"""Mixture-of-experts block: top-k router (softmax or deepseek-v3 sigmoid),
capacity-based padded dispatch (sort + scatter, token-dropping — the padded
grouped GEMM the paper's platform uses, §VII-C), shared experts, and the
load-balancing auxiliary loss.

Expert compute is an (E, C, d) x (E, d, h) grouped batched matmul — sharded
expert-parallel over 'model' when E divides the axis, else TP over the expert
hidden dim (grok-1: 8 experts on a 16-way axis).  The Pallas grouped-GEMM
kernel in ``repro.kernels.moe_gemm`` implements the same contraction for TPU.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, activation_fn
from repro.models.mlp import mlp, mlp_specs


def moe_specs(cfg) -> Dict[str, ParamSpec]:
    m = cfg.moe
    d, E, h = cfg.d_model, m.n_experts, m.d_expert
    s: Dict[str, ParamSpec] = {
        "router": ParamSpec((d, E), ("embed", "experts"), "normal", 0.02),
        "wg": ParamSpec((E, d, h), ("experts", "embed", "expert_ffn")),
        "wu": ParamSpec((E, d, h), ("experts", "embed", "expert_ffn")),
        "wd": ParamSpec((E, h, d), ("experts", "expert_ffn", "embed")),
    }
    if m.n_shared:
        # shared experts are always-on: computed as one fused wide MLP
        s["shared"] = {
            "wg": ParamSpec((d, m.n_shared * h), ("embed", "ffn")),
            "wu": ParamSpec((d, m.n_shared * h), ("embed", "ffn")),
            "wd": ParamSpec((m.n_shared * h, d), ("ffn", "embed")),
        }
    return s


def _route(cfg, logits):
    """-> (gates (T,k), idx (T,k), aux_loss scalar)."""
    m = cfg.moe
    if m.router == "sigmoid":                      # deepseek-v3 style
        scores = jax.nn.sigmoid(logits)
        gates, idx = jax.lax.top_k(scores, m.top_k)
        gates = gates / (gates.sum(-1, keepdims=True) + 1e-9)
        probs = scores / (scores.sum(-1, keepdims=True) + 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        gates, idx = jax.lax.top_k(probs, m.top_k)
        gates = gates / (gates.sum(-1, keepdims=True) + 1e-9)
    # load-balancing aux loss: E * sum_e f_e * P_e
    T = logits.shape[0]
    one_hot = jax.nn.one_hot(idx, m.n_experts, dtype=jnp.float32)  # (T,k,E)
    f_e = one_hot.sum((0, 1)) / (T * m.top_k)
    p_e = probs.mean(0)
    aux = m.aux_loss_weight * m.n_experts * jnp.sum(f_e * p_e)
    return gates, idx, aux


def capacity(cfg, n_tokens: int) -> int:
    m = cfg.moe
    c = int(n_tokens * m.top_k * m.capacity_factor / m.n_experts)
    return max(8, -(-c // 8) * 8)                  # round up to 8


def _dispatch_combine_local(cfg, p, xs, gates, idx):
    """Per-shard dispatch -> padded expert GEMMs -> combine.

    xs: (T_loc, d); gates/idx: (T_loc, k).  Purely local slot assignment —
    the production layout: capacity is PER DATA SHARD, so the scatter never
    crosses the data axis (a replicated global buffer forces the partitioner
    into per-layer all-reduces of the whole capacity buffer).
    """
    m = cfg.moe
    T, d = xs.shape
    E, k = m.n_experts, m.top_k
    C = capacity(cfg, T)

    flat_e = idx.reshape(T * k)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    flat_g = gates.reshape(T * k)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    counts = jnp.zeros(E, jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * k, dtype=jnp.int32) - starts[se]
    keep = pos < C
    dest = jnp.where(keep, se * C + pos, E * C)            # E*C = trash slot

    buf = jnp.zeros((E * C + 1, d), xs.dtype).at[dest].set(xs[st])
    eb = buf[: E * C].reshape(E, C, d)

    # ---- grouped expert GEMMs (padded — balanced compute, paper §VII-C) ----
    act = activation_fn(cfg.activation)
    h = act(jnp.einsum("ecd,edh->ech", eb, p["wg"].astype(xs.dtype)))
    h = h * jnp.einsum("ecd,edh->ech", eb, p["wu"].astype(xs.dtype))
    y = jnp.einsum("ech,ehd->ecd", h, p["wd"].astype(xs.dtype))

    # ---- combine: gather back, gate-weight, sum the k contributions --------
    yflat = jnp.concatenate([y.reshape(E * C, d),
                             jnp.zeros((1, d), xs.dtype)], 0)
    back = yflat[dest] * sg[:, None].astype(xs.dtype)
    return jnp.zeros((T, d), xs.dtype).at[st].add(back)


def moe_forward(cfg, p, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    from repro.parallel.act import constrain, data_extent

    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)

    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    gates, idx, aux = _route(cfg, logits)

    from repro.parallel.moe_shard_map import get_moe_dispatch
    from repro.parallel.act import _state
    mesh = getattr(_state, "mesh", None)
    rules = getattr(_state, "rules", None)
    if get_moe_dispatch() == "shard_map" and mesh is not None:
        from repro.parallel.moe_shard_map import moe_forward_shard_map
        out = moe_forward_shard_map(
            cfg, p, x, gates.reshape(B, S, -1), idx.reshape(B, S, -1),
            mesh, rules.get("act_batch", ()) if rules else ())
        out = out.reshape(T, d)
    else:
        # global-capacity pjit dispatch (per-data-shard vmapped dispatch was
        # measured NET-NEGATIVE on the 16x16 mesh — EXPERIMENTS.md §Perf
        # G2/G3: the partitioner replicates the vmapped scatter's backward);
        # the forced-local shard_map layout is G5.
        out = _dispatch_combine_local(cfg, p, xf, gates, idx)

    if m.n_shared:
        out = out + mlp(cfg, p["shared"], xf)
    return out.reshape(B, S, d), aux


def moe_or_mlp_specs(cfg, layer_is_dense: bool):
    if cfg.moe is None or layer_is_dense:
        d_ff = (cfg.moe.d_ff_dense if (cfg.moe and cfg.moe.d_ff_dense)
                else cfg.d_ff)
        return mlp_specs(cfg, d_ff)
    return moe_specs(cfg)
