"""Mamba-style selective SSM (hymba's parallel-SSM heads).

Training uses a chunked associative scan (chunk=256) so the (B,S,d_inner,
d_state) discretization tensors never materialize full-length — the same
blocking a TPU kernel would use for VMEM residency.  Decode carries
(conv_state, h) per layer.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec

CHUNK = 256


def ssm_dims(cfg) -> Tuple[int, int]:
    d_inner = cfg.ssm.expand * cfg.d_model
    dt_rank = cfg.ssm.dt_rank or -(-cfg.d_model // 16)
    return d_inner, dt_rank


def ssm_specs(cfg) -> Dict[str, ParamSpec]:
    c = cfg.ssm
    d = cfg.d_model
    di, dtr = ssm_dims(cfg)
    return {
        "in_proj": ParamSpec((d, 2 * di), ("embed", "ffn")),
        "conv_w": ParamSpec((c.d_conv, di), (None, "ffn")),
        "conv_b": ParamSpec((di,), ("ffn",), "zeros"),
        "x_proj": ParamSpec((di, dtr + 2 * c.d_state), ("ffn", None)),
        "dt_proj": ParamSpec((dtr, di), (None, "ffn")),
        "dt_bias": ParamSpec((di,), ("ffn",), "zeros"),
        "A_log": ParamSpec((di, c.d_state), ("ffn", None), "zeros"),
        "D": ParamSpec((di,), ("ffn",), "ones"),
        "out_proj": ParamSpec((di, d), ("ffn", "embed")),
    }


def _causal_depthwise_conv(x, w, b):
    """x: (B,S,di), w: (K,di) -> causal depthwise conv."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i: i + x.shape[1]] * w[i].astype(x.dtype)
              for i in range(K))
    return out + b.astype(x.dtype)


def _discretize(cfg, p, x_c):
    """x_c: (B,L,di) -> (deltaA (B,L,di,N), deltaBx (B,L,di,N), Cm (B,L,N))."""
    dtr = ssm_dims(cfg)[1]
    N = cfg.ssm.d_state
    dbc = x_c @ p["x_proj"].astype(x_c.dtype)
    dt, Bm, Cm = jnp.split(dbc, [dtr, dtr + N], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"].astype(x_c.dtype)
                         + p["dt_bias"].astype(x_c.dtype))   # (B,L,di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))             # (di,N)
    dtf = dt.astype(jnp.float32)
    deltaA = jnp.exp(dtf[..., None] * A)                     # (B,L,di,N)
    deltaBx = (dtf * x_c.astype(jnp.float32))[..., None] \
        * Bm.astype(jnp.float32)[:, :, None, :]
    return deltaA, deltaBx, Cm


def _scan_chunk(deltaA, deltaBx, h0):
    """Associative scan of h_t = a_t h_{t-1} + b_t within one chunk.

    deltaA/deltaBx: (B,L,di,N); h0: (B,di,N).  Returns (hs (B,L,di,N), h_last).
    """
    b = deltaBx.at[:, 0].add(deltaA[:, 0] * h0)
    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br
    _, hs = jax.lax.associative_scan(comb, (deltaA, b), axis=1)
    return hs, hs[:, -1]


def ssm_forward(cfg, p, x):
    """Training/prefill forward: x (B,S,d) -> (B,S,d)."""
    B, S, _ = x.shape
    di, _ = ssm_dims(cfg)
    N = cfg.ssm.d_state
    xz = x @ p["in_proj"].astype(x.dtype)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_c = jax.nn.silu(_causal_depthwise_conv(x_in, p["conv_w"], p["conv_b"]))

    L = min(CHUNK, S)
    n_chunks = -(-S // L)
    pad = n_chunks * L - S
    x_cp = jnp.pad(x_c, ((0, 0), (0, pad), (0, 0)))

    def step(h, xc_chunk):
        dA, dBx, Cm = _discretize(cfg, p, xc_chunk)
        hs, h_new = _scan_chunk(dA, dBx, h)
        y = jnp.einsum("blds,bls->bld", hs, Cm.astype(jnp.float32))
        return h_new, y

    xs = x_cp.reshape(B, n_chunks, L, di).transpose(1, 0, 2, 3)
    h0 = jnp.zeros((B, di, N), jnp.float32)
    _, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2, 3).reshape(B, n_chunks * L, di)[:, :S]
    y = y.astype(x.dtype) + x_c * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"].astype(x.dtype)


# ------------------------------------------------------------------ decode --
def ssm_init_state(cfg, batch: int):
    di, _ = ssm_dims(cfg)
    return {"conv": jnp.zeros((batch, cfg.ssm.d_conv - 1, di), jnp.float32),
            "h": jnp.zeros((batch, di, cfg.ssm.d_state), jnp.float32)}


def ssm_decode_step(cfg, p, x, state):
    """x: (B,1,d) -> (out (B,1,d), new state)."""
    di, _ = ssm_dims(cfg)
    xz = x @ p["in_proj"].astype(x.dtype)
    x_in, z = jnp.split(xz, 2, axis=-1)                      # (B,1,di)
    window = jnp.concatenate([state["conv"].astype(x.dtype), x_in], axis=1)
    # same ordered sum as _causal_depthwise_conv (bit-identical in bf16)
    K = p["conv_w"].shape[0]
    conv = sum(window[:, k] * p["conv_w"][k].astype(x.dtype)
               for k in range(K))
    x_c = jax.nn.silu(conv + p["conv_b"].astype(x.dtype))[:, None]  # (B,1,di)
    dA, dBx, Cm = _discretize(cfg, p, x_c)
    h = dA[:, 0] * state["h"] + dBx[:, 0]
    y = jnp.einsum("bds,bs->bd", h, Cm[:, 0].astype(jnp.float32))[:, None]
    y = y.astype(x.dtype) + x_c * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x.dtype)
    return out, {"conv": window[:, 1:].astype(jnp.float32), "h": h}
