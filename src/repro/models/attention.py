"""Attention: GQA SDPA (grouped, no materialized KV repeat), qk-norm, biases,
causal/sliding/bidirectional masks, cross-attention, and decode over KV caches
(full-length or sliding-window ring buffers).

Default backend is plain XLA einsums (what the dry-run lowers for the 512-chip
mesh); the Pallas flash-attention kernel from ``repro.kernels`` can be swapped
in with ``set_attention_impl("pallas")`` (validated in interpret mode on CPU).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, apply_rope, rmsnorm, rope_freqs

_ATTN_IMPL = "chunked"


def set_attention_impl(impl: str) -> None:
    """'chunked' (default): XLA flash-structured online-softmax over KV
    blocks — the faithful counterpart of the paper stack's FlashAttention
    (on real TPUs the Pallas kernel takes this role: 'pallas').
    'xla': naive S² materialization (ablation baseline)."""
    global _ATTN_IMPL
    assert impl in ("xla", "pallas", "chunked", "stub"), impl
    _ATTN_IMPL = impl


def get_attention_impl() -> str:
    return _ATTN_IMPL


# --------------------------------------------------------------------------- #
# Specs
# --------------------------------------------------------------------------- #
def attn_specs(cfg, kv_src_dim: Optional[int] = None) -> Dict[str, ParamSpec]:
    """Projection specs.  kv_src_dim != None -> cross-attention (kv from there).

    Logical axes: 'embed' is the FSDP-sharded model dim, 'heads'/'kv_heads'
    the TP-sharded flattened head dims (fallback to replicated handled by the
    rules engine when head counts don't divide the mesh).
    """
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    src = kv_src_dim if kv_src_dim is not None else d
    s: Dict[str, ParamSpec] = {
        "wq": ParamSpec((d, qd), ("embed", "heads")),
        "wk": ParamSpec((src, kvd), ("embed", "kv_heads")),
        "wv": ParamSpec((src, kvd), ("embed", "kv_heads")),
        "wo": ParamSpec((qd, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((qd,), ("heads",), "zeros")
        s["bk"] = ParamSpec((kvd,), ("kv_heads",), "zeros")
        s["bv"] = ParamSpec((kvd,), ("kv_heads",), "zeros")
    if cfg.qk_norm:
        s["q_norm"] = ParamSpec((cfg.head_dim,), (None,), "ones")
        s["k_norm"] = ParamSpec((cfg.head_dim,), (None,), "ones")
    return s


# --------------------------------------------------------------------------- #
# Projections
# --------------------------------------------------------------------------- #
def project_q(cfg, p, x, positions=None):
    B, S, _ = x.shape
    q = x @ p["wq"].astype(x.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
    if positions is not None and cfg.pos_embedding == "rope":
        cos, sin = rope_freqs(positions, cfg.head_dim, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
    return q


def project_kv(cfg, p, x, positions=None):
    B, S, _ = x.shape
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if "bk" in p:
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if positions is not None and cfg.pos_embedding == "rope":
        cos, sin = rope_freqs(positions, cfg.head_dim, cfg.rope_theta)
        k = apply_rope(k, cos, sin)
    return k, v


# --------------------------------------------------------------------------- #
# Masks
# --------------------------------------------------------------------------- #
def make_mask(Sq: int, Sk: int, *, causal: bool, window: int = 0,
              offset: int = 0):
    """(Sq, Sk) bool mask.  offset = absolute position of query 0 minus key 0."""
    qi = jnp.arange(Sq)[:, None] + offset
    ki = jnp.arange(Sk)[None, :]
    m = jnp.ones((Sq, Sk), bool)
    if causal:
        m &= ki <= qi
    if window:
        m &= ki > qi - window
    return m


# --------------------------------------------------------------------------- #
# Core SDPA (grouped-query, fp32 softmax)
# --------------------------------------------------------------------------- #
def sdpa(q, k, v, mask=None):
    """q: (B,Sq,H,D), k/v: (B,Sk,kvH,D); returns (B,Sq,H,D).

    GQA is computed grouped — q reshaped to (kvH, group) — so KV is never
    materialized H-wide (keeps HBM traffic and TP resharding minimal).
    """
    if _ATTN_IMPL == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, mask=mask)
    B, Sq, H, D = q.shape
    kvH = k.shape[2]
    G = H // kvH
    qg = q.reshape(B, Sq, kvH, G, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (D ** -0.5)
    if mask is not None:
        while mask.ndim < scores.ndim:
            mask = mask[None]
        scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(v.dtype), v)
    return out.reshape(B, Sq, H, D)


_FLASH_REMAT = True


def set_flash_remat(on: bool) -> None:
    """Flash-style recompute: checkpoint the KV-chunk body so the backward
    re-materializes chunk scores instead of saving all of them (what the
    Pallas kernel does in VMEM).  Off = save-all (ablation)."""
    global _FLASH_REMAT
    _FLASH_REMAT = on


def sdpa_flash(q, k, v, *, causal=True, window_eff=0, chunk: int = 1024,
               q_offset=0):
    """XLA flash-structured attention: lax.scan over KV chunks with online
    softmax — O(S·chunk) live scores instead of O(S²).  window_eff may be a
    traced scalar (hymba per-layer global/sliding selection)."""
    B, Sq, H, D = q.shape
    Sk, kvH = k.shape[1], k.shape[2]
    G = H // kvH
    C = min(chunk, Sk)
    n = -(-Sk // C)
    pad = n * C - Sk
    if pad:
        padw = ((0, 0), (0, pad), (0, 0), (0, 0))
        k, v = jnp.pad(k, padw), jnp.pad(v, padw)
    from repro.parallel.act import constrain
    qg = q.reshape(B, Sq, kvH, G, D)
    # keep attention sequence-sharded end to end (SP through the mixer):
    # scores stay (B,kvH,G,Sq/tp,C) local, KV chunks replicate over 'model'
    # (tiny) — avoids the partitioner's seq<->head all-to-all reshard.
    qg = constrain(qg, "act_batch", "act_seq", None, None, None)
    kc = k.reshape(B, n, C, kvH, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n, C, kvH, D).transpose(1, 0, 2, 3, 4)
    kc = constrain(kc, None, "act_batch", None, None, None)
    vc = constrain(vc, None, "act_batch", None, None, None)
    qi = (jnp.arange(Sq) + q_offset)[:, None]                 # (Sq, 1)
    scale = D ** -0.5

    def body(carry, inp):
        m, l, acc = carry
        j, kj, vj = inp
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kj,
                       preferred_element_type=jnp.float32) * scale
        ki = j * C + jnp.arange(C)[None, :]                   # (1, C)
        valid = ki < Sk
        if causal:
            valid &= ki <= qi
        if not (isinstance(window_eff, int) and window_eff == 0):
            w = window_eff
            valid &= (w == 0) | (ki > qi - w)
        s = jnp.where(valid[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1, keepdims=True))
        p_ = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = alpha * l + p_.sum(-1, keepdims=True)
        acc = acc * alpha + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p_.astype(vj.dtype), vj)
        return (m_new, l, acc), None

    if _FLASH_REMAT:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable,
            prevent_cse=False)
    m0 = jnp.full((B, kvH, G, Sq, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((B, kvH, G, Sq, 1), jnp.float32)
    a0 = jnp.zeros((B, kvH, G, Sq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(n), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D).astype(q.dtype)


def sdpa_stub(q, k, v):
    """Shape-preserving near-zero-traffic stand-in used by the dry-run's
    kernel-adjustment methodology (EXPERIMENTS.md §Perf): attention traffic
    = T(real lowering) - T(stub lowering); the Pallas kernel's true HBM
    traffic (q,k,v,o block sweeps) is added back analytically."""
    B, Sq, H, D = q.shape
    kvH = k.shape[2]
    pooled = v.mean(axis=1, keepdims=True)               # (B,1,kvH,D)
    out = jnp.repeat(pooled, H // kvH, axis=2)           # (B,1,H,D)
    return jnp.broadcast_to(out, (B, Sq, H, D)) + 0.0 * q


def sdpa_auto(q, k, v, *, causal, window_eff=0, q_offset=0, mask=None):
    """Dispatch: chunked flash structure for multi-token attention, naive
    masked SDPA otherwise (decode / explicit masks / pallas)."""
    if _ATTN_IMPL == "stub" and q.shape[1] > 1:
        return sdpa_stub(q, k, v)
    if (_ATTN_IMPL == "chunked" and q.shape[1] > 1 and mask is None):
        return sdpa_flash(q, k, v, causal=causal, window_eff=window_eff,
                          q_offset=q_offset)
    if mask is None:
        Sq, Sk = q.shape[1], k.shape[1]
        qi = (jnp.arange(Sq) + q_offset)[:, None]
        ki = jnp.arange(Sk)[None, :]
        mask = jnp.ones((Sq, Sk), bool)
        if causal:
            mask &= ki <= qi
        if not (isinstance(window_eff, int) and window_eff == 0):
            mask &= (window_eff == 0) | (ki > qi - window_eff)
    return sdpa(q, k, v, mask)


def attention(cfg, p, x, positions, mask, kv_x=None, kv_positions=None,
              *, causal=False, window_eff=0):
    """Full self/cross attention for training & prefill.  Returns (B,S,d).

    mask=None + causal/window_eff semantics -> flash-structured path;
    an explicit mask array forces the naive path.
    """
    q = project_q(cfg, p, x, positions)
    src = kv_x if kv_x is not None else x
    kpos = None if kv_x is not None else positions
    if kv_x is not None and kv_positions is not None:
        kpos = kv_positions
    k, v = project_kv(cfg, p, src, kpos)
    out = sdpa_auto(q, k, v, causal=causal, window_eff=window_eff, mask=mask)
    B, S = x.shape[:2]
    return out.reshape(B, S, cfg.q_dim) @ p["wo"].astype(x.dtype)


# --------------------------------------------------------------------------- #
# Decode over caches
# --------------------------------------------------------------------------- #
def cache_update(k_cache, v_cache, k_new, v_new, pos, *, ring: bool):
    """Insert (B,1,kvH,D) entries at pos (ring: pos % window)."""
    W = k_cache.shape[1]
    idx = jax.lax.rem(pos, W) if ring else pos
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k_new.astype(k_cache.dtype), (0, idx, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v_new.astype(v_cache.dtype), (0, idx, 0, 0))
    return k_cache, v_cache


def decode_attention(cfg, p, x, pos, k_cache, v_cache, *, ring: bool,
                     is_global=None):
    """One-token decode: x (B,1,d), caches (B,W,kvH,D).  Returns out, caches.

    ring=True -> sliding-window ring buffer (cache positions are pos%W).
    is_global: optional traced bool (hymba): when True the window constraint
    is dropped (only meaningful for non-ring full-length caches).
    """
    B = x.shape[0]
    W = k_cache.shape[1]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q = project_q(cfg, p, x, positions)
    k_new, v_new = project_kv(cfg, p, x, positions)
    k_cache, v_cache = cache_update(k_cache, v_cache, k_new, v_new, pos,
                                    ring=ring)
    if ring:
        slot_pos = pos - jax.lax.rem(pos - jnp.arange(W, dtype=jnp.int32)
                                     + W, jnp.int32(W))
        valid = (slot_pos >= 0) & (slot_pos <= pos)
        if cfg.window:
            valid &= slot_pos > pos - cfg.window
    else:
        kpos = jnp.arange(W, dtype=jnp.int32)
        valid = kpos <= pos
        if cfg.window:
            win_ok = kpos > pos - cfg.window
            if is_global is not None:
                win_ok = win_ok | is_global
            valid &= win_ok
    out = sdpa(q, k_cache, v_cache, valid[None, None, :])
    out = out.reshape(B, 1, cfg.q_dim) @ p["wo"].astype(x.dtype)
    return out, k_cache, v_cache
