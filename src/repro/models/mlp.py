"""Dense MLP blocks (gated silu/gelu, squared-relu non-gated)."""
from __future__ import annotations

from typing import Dict

from repro.models.common import ParamSpec, activation_fn


def mlp_specs(cfg, d_ff: int) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    s = {"wd": ParamSpec((d_ff, d), ("ffn", "embed"))}
    if cfg.gated_mlp:
        s["wg"] = ParamSpec((d, d_ff), ("embed", "ffn"))
        s["wu"] = ParamSpec((d, d_ff), ("embed", "ffn"))
    else:
        s["wu"] = ParamSpec((d, d_ff), ("embed", "ffn"))
    return s


def mlp(cfg, p, x):
    act = activation_fn(cfg.activation)
    dt = x.dtype
    if cfg.gated_mlp:
        h = act(x @ p["wg"].astype(dt)) * (x @ p["wu"].astype(dt))
    else:
        h = act(x @ p["wu"].astype(dt))
    return h @ p["wd"].astype(dt)
