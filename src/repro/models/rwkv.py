"""RWKV6 "Finch" blocks: time-mix with data-dependent decay + channel-mix.

The WKV recurrence  S_t = diag(w_t) S_{t-1} + k_t v_t^T,
                    y_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
is computed in CHUNKED parallel form for training (intra-chunk pairwise decay
matrix — all exponents <= 0, numerically safe — plus an inter-chunk state
scan), matching the blocking of the Pallas kernel in
``repro.kernels.rwkv6_wkv``.  Decode is the exact single-step recurrence.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec

WKV_CHUNK = 16
_MIX_NAMES = ("r", "k", "v", "w", "g")


def rwkv_dims(cfg) -> Tuple[int, int]:
    H = cfg.d_model // cfg.rwkv.head_dim
    return H, cfg.rwkv.head_dim


def time_mix_specs(cfg) -> Dict[str, ParamSpec]:
    c = cfg.rwkv
    d = cfg.d_model
    H, Dh = rwkv_dims(cfg)
    return {
        "maa_x": ParamSpec((d,), (None,), "zeros"),
        "maa": ParamSpec((5, d), (None, None), "zeros"),        # r,k,v,w,g bases
        "tm_w1": ParamSpec((d, 5 * c.mix_lora), ("embed", None), "normal", 0.01),
        "tm_w2": ParamSpec((5, c.mix_lora, d), (None, None, "embed"),
                           "normal", 0.01),
        "wr": ParamSpec((d, d), ("embed", "heads")),
        "wk": ParamSpec((d, d), ("embed", "heads")),
        "wv": ParamSpec((d, d), ("embed", "heads")),
        "wg": ParamSpec((d, d), ("embed", "heads")),
        "wo": ParamSpec((d, d), ("heads", "embed")),
        "w0": ParamSpec((d,), (None,), "zeros"),
        "w1": ParamSpec((d, c.decay_lora), ("embed", None), "normal", 0.01),
        "w2": ParamSpec((c.decay_lora, d), (None, "embed"), "normal", 0.01),
        "u": ParamSpec((H, Dh), (None, None), "normal", 1.0),   # time_first
        "ln_x_w": ParamSpec((d,), (None,), "ones"),
        "ln_x_b": ParamSpec((d,), (None,), "zeros"),
    }


def channel_mix_specs(cfg) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    h = cfg.d_ff
    return {
        "maa_k": ParamSpec((d,), (None,), "zeros"),
        "maa_r": ParamSpec((d,), (None,), "zeros"),
        "wk": ParamSpec((d, h), ("embed", "ffn")),
        "wv": ParamSpec((h, d), ("ffn", "embed")),
        "wr": ParamSpec((d, d), ("embed", None)),
    }


def _token_shift(x, last=None):
    """Shift right by one along time; position 0 gets `last` (or zeros)."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _time_mix_inputs(cfg, p, x, shifted):
    """Data-dependent 5-way token-shift interpolation -> dict of mixed inputs."""
    dx = shifted - x
    xxx = x + dx * p["maa_x"].astype(x.dtype)
    B, S, d = x.shape
    lora = jnp.tanh(xxx @ p["tm_w1"].astype(x.dtype))
    lora = lora.reshape(B, S, 5, cfg.rwkv.mix_lora)
    lora = jnp.einsum("bsfm,fmd->bsfd", lora, p["tm_w2"].astype(x.dtype))
    out = {}
    for i, name in enumerate(_MIX_NAMES):
        mix = p["maa"][i].astype(x.dtype) + lora[:, :, i]
        out[name] = x + dx * mix
    return out


def _decay(cfg, p, xw):
    """Per-channel log-decay (< 0): log w = -exp(w0 + lora_w(xw))."""
    lw = jnp.tanh(xw @ p["w1"].astype(xw.dtype)) @ p["w2"].astype(xw.dtype)
    return -jnp.exp(jnp.clip(p["w0"].astype(jnp.float32)
                             + lw.astype(jnp.float32), -20.0, 10.0))


def wkv_chunked(r, k, v, w_log, u, state=None, chunk: int = WKV_CHUNK):
    """Chunked-parallel WKV6.  r,k,v,w_log: (B,S,H,D); u: (H,D).

    Returns (y (B,S,H,D), final state (B,H,D,D)).  All intra-chunk decay
    exponents are differences of a cumsum of negatives -> <= 0 -> exp safe.
    """
    B, S, H, D = r.shape
    L = min(chunk, S)
    n = -(-S // L)
    pad = n * L - S

    def pad_t(x):
        return jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
    # (n, B, H, L, D) chunks, fp32 for the recurrence
    def chunks(x):
        x = pad_t(x).astype(jnp.float32)
        return x.reshape(B, n, L, H, D).transpose(1, 0, 3, 2, 4)
    rc, kc, vc, wc = chunks(r), chunks(k), chunks(v), chunks(w_log)
    uf = u.astype(jnp.float32)

    if state is None:
        state = jnp.zeros((B, H, D, D), jnp.float32)

    def step(S_prev, inp):
        rr, kk, vv, ww = inp                      # (B,H,L,D)
        cw = jnp.cumsum(ww, axis=2)               # inclusive cumsum of log w
        cwx = cw - ww                             # exclusive (decay to t-1)
        # inter-chunk: y_i += (r_i * exp(cwx_i)) @ S_prev
        r_in = rr * jnp.exp(cwx)
        y_inter = jnp.einsum("bhld,bhde->bhle", r_in, S_prev)
        # intra-chunk: A_ij = sum_d r_i k_j exp(cwx_i - cw_j), j < i
        expo = cwx[:, :, :, None, :] - cw[:, :, None, :, :]   # (B,H,L,L,D)
        tri = jnp.tril(jnp.ones((L, L), bool), k=-1)[None, None, :, :, None]
        pair = jnp.where(tri, jnp.exp(jnp.minimum(expo, 0.0)), 0.0)
        A = jnp.einsum("bhid,bhjd,bhijd->bhij", rr, kk, pair)
        # diagonal bonus: u-weighted current token
        diag = jnp.einsum("bhld,bhld->bhl", rr * uf[None, :, None, :], kk)
        y = y_inter + jnp.einsum("bhij,bhjd->bhid", A, vv) \
            + diag[..., None] * vv
        # state update: S_new = diag(exp(cw_last)) S + sum_j exp(cw_last-cw_j) k_j v_j^T
        decay_all = jnp.exp(cw[:, :, -1:, :] - cw)            # (B,H,L,D) <= 1
        k_scaled = kk * decay_all
        S_new = S_prev * jnp.exp(cw[:, :, -1, :])[..., None] \
            + jnp.einsum("bhld,bhle->bhde", k_scaled, vv)
        return S_new, y

    S_fin, ys = jax.lax.scan(step, state, (rc, kc, vc, wc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, n * L, H, D)[:, :S]
    return y, S_fin


def wkv_step(r, k, v, w_log, u, state):
    """Exact one-token recurrence.  r,k,v,w_log: (B,H,D); state (B,H,D,D)."""
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w_log))
    kv = kf[..., :, None] * vf[..., None, :]                 # (B,H,D,D)
    att = state + u.astype(jnp.float32)[None, :, :, None] * kv
    y = jnp.einsum("bhd,bhde->bhe", rf, att)
    state = state * jnp.exp(wf)[..., None] + kv
    return y, state


def _group_norm(x, w, b, H, eps=1e-5):
    """GroupNorm with H groups over the flattened head dim (RWKV ln_x)."""
    B, S, d = x.shape
    xg = x.reshape(B, S, H, d // H).astype(jnp.float32)
    mu = xg.mean(-1, keepdims=True)
    var = ((xg - mu) ** 2).mean(-1, keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    out = xg.reshape(B, S, d) * w.astype(jnp.float32) + b.astype(jnp.float32)
    return out.astype(x.dtype)


def time_mix(cfg, p, x, shift_state=None, wkv_state=None):
    """Full time-mix layer.  x: (B,S,d).  Returns (out, (shift, wkv) states)."""
    B, S, d = x.shape
    H, D = rwkv_dims(cfg)
    shifted = _token_shift(x, shift_state)
    mixed = _time_mix_inputs(cfg, p, x, shifted)
    dt = x.dtype

    def heads(name, wname):
        return (mixed[name] @ p[wname].astype(dt)).reshape(B, S, H, D)
    r, k, v = heads("r", "wr"), heads("k", "wk"), heads("v", "wv")
    g = jax.nn.silu(mixed["g"] @ p["wg"].astype(dt))
    w_log = _decay(cfg, p, mixed["w"]).reshape(B, S, H, D)

    if S == 1 and wkv_state is not None:
        y, wkv_state = wkv_step(r[:, 0], k[:, 0], v[:, 0], w_log[:, 0],
                                p["u"], wkv_state)
        y = y[:, None].reshape(B, 1, d).astype(dt)
    else:
        y, wkv_state = wkv_chunked(r, k, v, w_log, p["u"], wkv_state)
        y = y.reshape(B, S, d).astype(dt)
    y = _group_norm(y, p["ln_x_w"], p["ln_x_b"], H) * g
    out = y @ p["wo"].astype(dt)
    return out, x[:, -1:], wkv_state


def channel_mix(cfg, p, x, shift_state=None):
    shifted = _token_shift(x, shift_state)
    dx = shifted - x
    xk = x + dx * p["maa_k"].astype(x.dtype)
    xr = x + dx * p["maa_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["wk"].astype(x.dtype)))
    v = k @ p["wv"].astype(x.dtype)
    r = jax.nn.sigmoid(xr @ p["wr"].astype(x.dtype))
    return r * v, x[:, -1:]
