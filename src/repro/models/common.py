"""Shared model-building blocks: param specs, norms, activations, RoPE, loss.

Pure JAX (no flax).  A model is a tree of ``ParamSpec`` (single source of
truth for shape, logical sharding axes and initializer); ``init_params``
materializes arrays, ``logical_axes`` extracts the sharding tree that
``repro.parallel.sharding`` maps onto the mesh.
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------- #
# Param specs
# --------------------------------------------------------------------------- #
class ParamSpec(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis name per dim (None = replicated)
    init: str = "normal"              # normal | zeros | ones | embed
    scale: float = -1.0               # -1 -> 1/sqrt(fan_in)


def _fan_in(shape: Tuple[int, ...]) -> int:
    # stacked layer axes don't count toward fan-in
    return shape[-2] if len(shape) >= 2 else shape[-1]


def init_params(spec_tree, key, dtype=jnp.float32):
    leaves, treedef = jax.tree_util.tree_flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    out = []
    for spec, k in zip(leaves, keys):
        if spec.init == "zeros":
            arr = jnp.zeros(spec.shape, dtype)
        elif spec.init == "ones":
            arr = jnp.ones(spec.shape, dtype)
        else:
            scale = spec.scale
            if scale < 0:
                scale = 1.0 / math.sqrt(max(_fan_in(spec.shape), 1))
            if spec.init == "embed":
                scale = 0.02
            arr = (jax.random.normal(k, spec.shape, jnp.float32)
                   * scale).astype(dtype)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(spec_tree, dtype=jnp.float32):
    """ShapeDtypeStruct tree (no allocation) — used by the dry-run."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def logical_axes(spec_tree):
    return jax.tree_util.tree_map(
        lambda s: s.axes, spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec))


def cast_tree(tree, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating)
        else x, tree)


def param_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


# --------------------------------------------------------------------------- #
# Norms / activations
# --------------------------------------------------------------------------- #
def rmsnorm(x, weight, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def layernorm(x, weight, bias, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dt)


def norm_spec(cfg, d: int, prefix: Tuple[int, ...] = ()) -> Dict[str, ParamSpec]:
    lead = tuple(prefix)
    lead_ax = ("layers",) * len(prefix)
    if cfg.norm == "layernorm":
        return {"w": ParamSpec(lead + (d,), lead_ax + (None,), "ones"),
                "b": ParamSpec(lead + (d,), lead_ax + (None,), "zeros")}
    return {"w": ParamSpec(lead + (d,), lead_ax + (None,), "ones")}


def apply_norm(cfg, p, x):
    if cfg.norm == "layernorm":
        return layernorm(x, p["w"], p["b"], cfg.norm_eps)
    return rmsnorm(x, p["w"], cfg.norm_eps)


def activation_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "squared_relu":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name}")


def softcap(logits, cap: float):
    if cap and cap > 0:
        return cap * jnp.tanh(logits / cap)
    return logits


# --------------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------------- #
def rope_freqs(positions, head_dim: int, theta: float):
    """cos/sin tables for given positions: (..., head_dim//2) each."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                           / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv       # (..., hd/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, D); cos/sin: (B, S, D/2) or (S, D/2)."""
    d = x.shape[-1]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    cos, sin = cos[..., None, :], sin[..., None, :]             # head axis
    while cos.ndim < x.ndim:                                    # left-pad batch
        cos, sin = cos[None], sin[None]
    xf1 = x1.astype(jnp.float32)
    xf2 = x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------- #
# Loss
# --------------------------------------------------------------------------- #
def cross_entropy_loss(logits, labels, z_loss_weight: float = 0.0,
                       ignore_index: int = -100):
    """Mean CE over non-ignored tokens, with optional z-loss regularizer.

    logits: (..., V) any float dtype; labels: (...) int32.
    """
    logits = logits.astype(jnp.float32)
    mask = (labels != ignore_index)
    safe = jnp.where(mask, labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    ce = (lse - ll) * mask
    denom = jnp.maximum(mask.sum(), 1)
    loss = ce.sum() / denom
    metrics = {"ce_loss": loss, "tokens": mask.sum()}
    if z_loss_weight:
        zl = z_loss_weight * jnp.sum(jnp.square(lse) * mask) / denom
        metrics["z_loss"] = zl
        loss = loss + zl
    return loss, metrics


# --------------------------------------------------------------------------- #
# Misc
# --------------------------------------------------------------------------- #
def pad_vocab(v: int, multiple: int = 128) -> int:
    """Pad vocab so TP over the production mesh divides evenly."""
    return -(-v // multiple) * multiple


def take_embedding(table, tokens):
    return jnp.take(table, tokens, axis=0)


def stack_specs(spec_tree, n: int):
    """Prepend a stacked 'layers' axis to every spec in a layer's spec tree."""
    def f(s: ParamSpec) -> ParamSpec:
        return ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale)
    return jax.tree_util.tree_map(
        f, spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def tree_get(tree, idx: int):
    """Index a stacked-params tree along axis 0 (for non-scan layer loops)."""
    return jax.tree_util.tree_map(lambda x: x[idx], tree)
