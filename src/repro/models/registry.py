"""build_model(cfg) -> model instance + batch/input-spec builders.

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input
(weak-type-correct, shardable, no device allocation) — the dry-run's input
contract per the deliverable spec.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.encdec import EncDecLM
from repro.models.rwkv6 import RWKV6LM
from repro.models.transformer import DecoderOnlyLM
from repro.models.vision import VisionLM

_FAMILIES = {
    "dense": DecoderOnlyLM,
    "moe": DecoderOnlyLM,
    "hybrid": DecoderOnlyLM,
    "encdec": EncDecLM,
    "vlm": VisionLM,
    "rwkv": RWKV6LM,
}


def build_model(cfg: ModelConfig, *, max_cache_len: int = 0,
                remat: str = "nothing", scan_layers: bool = True):
    cls = _FAMILIES[cfg.family]
    return cls(cfg, max_cache_len=max_cache_len, remat=remat,
               scan_layers=scan_layers)


def batch_extras(cfg: ModelConfig, batch_size: int, rng=None) -> Dict[str, Any]:
    """Concrete modality-stub inputs (smoke tests / examples)."""
    import numpy as np
    rng = rng or np.random.default_rng(0)
    out: Dict[str, Any] = {}
    if cfg.family == "vlm":
        out["vision_embeds"] = jnp.asarray(rng.normal(
            0, 1, (batch_size, cfg.vision.vision_seq, cfg.vision.vision_dim)
        ).astype("float32"))
    if cfg.family == "encdec":
        out["frames"] = jnp.asarray(rng.normal(
            0, 1, (batch_size, cfg.audio.frame_seq, cfg.audio.frame_dim)
        ).astype("float32"))
    return out


def make_batch(cfg: ModelConfig, batch_size: int, seq_len: int,
               seed: int = 0) -> Dict[str, Any]:
    """Concrete random batch for smoke tests."""
    import numpy as np
    rng = np.random.default_rng(seed)
    tokens = rng.integers(0, cfg.vocab_size, (batch_size, seq_len))
    batch = {"tokens": jnp.asarray(tokens, jnp.int32),
             "labels": jnp.asarray(np.roll(tokens, -1, axis=1), jnp.int32)}
    batch.update(batch_extras(cfg, batch_size))
    return batch


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every train/serve input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    if shape.kind == "train":
        specs: Dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
    elif shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    else:                                        # decode: one new token
        specs = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    if cfg.family == "vlm" and shape.kind != "decode":
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.vision.vision_seq, cfg.vision.vision_dim), f32)
    if cfg.family == "encdec" and shape.kind != "decode":
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.audio.frame_seq, cfg.audio.frame_dim), f32)
    return specs
