"""Llama-3.2-Vision-style VLM backbone: interleaved gated cross-attention
layers (every k-th layer attends to vision embeddings).  The vision frontend
is a STUB per the assignment: inputs are precomputed patch embeddings
(B, Sv, vision_dim) projected into d_model by a learned connector.

Layers run as an outer scan over groups of (k-1 self layers + 1 cross layer);
the k-1 self layers are an inner scan — compile-time stays O(1) in depth.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import (ParamSpec, apply_norm, cross_entropy_loss,
                                 norm_spec, pad_vocab, stack_specs,
                                 take_embedding)
from repro.models.mlp import mlp, mlp_specs
from repro.parallel.act import shard_residual
from repro.models.transformer import REMAT_POLICIES


class VisionLM:
    def __init__(self, cfg, *, max_cache_len: int = 0,
                 remat: str = "nothing", scan_layers: bool = True):
        self.cfg = cfg
        self.vp = pad_vocab(cfg.vocab_size)
        self.max_cache_len = max_cache_len or cfg.max_seq_len
        self.remat = remat
        k = cfg.vision.cross_attn_every
        assert cfg.n_layers % k == 0, "n_layers must divide by cross interval"
        self.n_groups = cfg.n_layers // k
        self.self_per_group = k - 1

    # ----------------------------------------------------------------- specs
    def _self_specs(self):
        cfg = self.cfg
        return {"ln1": norm_spec(cfg, cfg.d_model),
                "attn": attn.attn_specs(cfg),
                "ln2": norm_spec(cfg, cfg.d_model),
                "ffn": mlp_specs(cfg, cfg.d_ff)}

    def _cross_specs(self):
        cfg = self.cfg
        return {"ln1": norm_spec(cfg, cfg.d_model),
                "xattn": attn.attn_specs(cfg),
                "gate_attn": ParamSpec((), (), "zeros"),
                "ln2": norm_spec(cfg, cfg.d_model),
                "ffn": mlp_specs(cfg, cfg.d_ff),
                "gate_ffn": ParamSpec((), (), "zeros")}

    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        v = cfg.vision
        return {
            "embed": ParamSpec((self.vp, cfg.d_model), ("vocab", "embed"),
                               "embed"),
            "vision_proj": ParamSpec((v.vision_dim, cfg.d_model),
                                     (None, "embed")),
            "groups": {
                "selfs": stack_specs(stack_specs(self._self_specs(),
                                                 self.self_per_group),
                                     self.n_groups),
                "cross": stack_specs(self._cross_specs(), self.n_groups),
            },
            "final_norm": norm_spec(cfg, cfg.d_model),
            "lm_head": ParamSpec((cfg.d_model, self.vp), ("embed", "vocab")),
        }

    # --------------------------------------------------------------- helpers
    def _vision_embed(self, params, vision_embeds):
        dt = jnp.dtype(self.cfg.compute_dtype)
        return vision_embeds.astype(dt) @ params["vision_proj"].astype(dt)

    def _self_block(self, lp, x, positions, mask=None):
        cfg = self.cfg
        x = shard_residual(x)
        h = apply_norm(cfg, lp["ln1"], x)
        x = x + attn.attention(cfg, lp["attn"], h, positions, None,
                               causal=True)
        h = apply_norm(cfg, lp["ln2"], x)
        return x + mlp(cfg, lp["ffn"], h)

    def _cross_block(self, lp, x, vis):
        cfg = self.cfg
        x = shard_residual(x)
        h = apply_norm(cfg, lp["ln1"], x)
        a = attn.attention(cfg, lp["xattn"], h, None, None, kv_x=vis,
                           causal=False)
        x = x + jnp.tanh(lp["gate_attn"]).astype(x.dtype) * a
        h = apply_norm(cfg, lp["ln2"], x)
        f = mlp(cfg, lp["ffn"], h)
        return x + jnp.tanh(lp["gate_ffn"]).astype(x.dtype) * f

    # --------------------------------------------------------------- forward
    def forward(self, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        vis = self._vision_embed(params, batch["vision_embeds"])
        positions = jnp.arange(S, dtype=jnp.int32)
        x = take_embedding(params["embed"], tokens).astype(vis.dtype)

        def inner(x, lp):
            return self._self_block(lp, x, positions, None), None

        def outer(x, gp):
            x, _ = jax.lax.scan(inner, x, gp[0])
            return self._cross_block(gp[1], x, vis), None

        outer = jax.checkpoint(outer, policy=REMAT_POLICIES[self.remat],
                               prevent_cse=False)
        x, _ = jax.lax.scan(outer, x, (params["groups"]["selfs"],
                                       params["groups"]["cross"]))
        return self._logits(params, x), jnp.zeros((), jnp.float32)

    def _logits(self, params, x):
        cfg = self.cfg
        x = apply_norm(cfg, params["final_norm"], x)
        logits = x @ params["lm_head"].astype(x.dtype)
        if self.vp != cfg.vocab_size:
            logits = jnp.where(jnp.arange(self.vp) < cfg.vocab_size,
                               logits, -1e30)
        return logits

    def loss(self, params, batch):
        logits, aux = self.forward(params, batch)
        loss, metrics = cross_entropy_loss(logits, batch["labels"])
        return loss, metrics

    # ---------------------------------------------------------------- decode
    def init_cache(self, batch: int, dtype=jnp.bfloat16) -> Dict[str, Any]:
        cfg = self.cfg
        W = self.max_cache_len
        kv = (self.n_groups, self.self_per_group, batch, W, cfg.n_kv_heads,
              cfg.head_dim)
        xv = (self.n_groups, batch, cfg.vision.vision_seq, cfg.n_kv_heads,
              cfg.head_dim)
        return {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype),
                "xk": jnp.zeros(xv, dtype), "xv": jnp.zeros(xv, dtype),
                "pos": jnp.zeros((), jnp.int32)}

    def cache_axes(self):
        kv = ("layers", "layers", "act_batch", "window", "kv_heads", None)
        xv = ("layers", "act_batch", None, "kv_heads", None)
        return {"k": kv, "v": kv, "xk": xv, "xv": xv, "pos": ()}

    def prefill(self, params, batch, cache=None):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        if cache is None:
            cache = self.init_cache(B)
        vis = self._vision_embed(params, batch["vision_embeds"])
        positions = jnp.arange(S, dtype=jnp.int32)
        x = take_embedding(params["embed"], tokens).astype(vis.dtype)

        def inner(x, lp):
            h = apply_norm(cfg, lp["ln1"], x)
            q = attn.project_q(cfg, lp["attn"], h, positions)
            k, v = attn.project_kv(cfg, lp["attn"], h, positions)
            a = attn.sdpa_auto(q, k, v, causal=True).reshape(B, S, cfg.q_dim)
            x = x + a @ lp["attn"]["wo"].astype(x.dtype)
            h = apply_norm(cfg, lp["ln2"], x)
            return x + mlp(cfg, lp["ffn"], h), {"k": k, "v": v}

        def outer(x, gp):
            x, kv = jax.lax.scan(inner, x, gp[0])
            lp = gp[1]
            h = apply_norm(cfg, lp["ln1"], x)
            xk, xv = attn.project_kv(cfg, lp["xattn"], vis, None)
            q = attn.project_q(cfg, lp["xattn"], h, None)
            a = attn.sdpa_auto(q, xk, xv, causal=False).reshape(B, S, cfg.q_dim)
            a = a @ lp["xattn"]["wo"].astype(x.dtype)
            x = x + jnp.tanh(lp["gate_attn"]).astype(x.dtype) * a
            h = apply_norm(cfg, lp["ln2"], x)
            f = mlp(cfg, lp["ffn"], h)
            x = x + jnp.tanh(lp["gate_ffn"]).astype(x.dtype) * f
            return x, {"k": kv["k"], "v": kv["v"], "xk": xk, "xv": xv}

        x, ys = jax.lax.scan(outer, x, (params["groups"]["selfs"],
                                        params["groups"]["cross"]))
        W = self.max_cache_len
        pad = ((0, 0), (0, 0), (0, 0), (0, W - S), (0, 0), (0, 0))
        cache = dict(cache)
        cache["k"] = jnp.pad(ys["k"], pad).astype(cache["k"].dtype)
        cache["v"] = jnp.pad(ys["v"], pad).astype(cache["v"].dtype)
        cache["xk"] = ys["xk"].astype(cache["xk"].dtype)
        cache["xv"] = ys["xv"].astype(cache["xv"].dtype)
        cache["pos"] = jnp.asarray(S, jnp.int32)
        return self._logits(params, x[:, -1:]), cache

    def decode_step(self, params, tokens, cache):
        cfg = self.cfg
        B = tokens.shape[0]
        pos = cache["pos"]
        x = take_embedding(params["embed"], tokens).astype(
            jnp.dtype(cfg.compute_dtype))

        def inner(x, xs):
            lp, kc, vc = xs
            h = apply_norm(cfg, lp["ln1"], x)
            a, kc, vc = attn.decode_attention(cfg, lp["attn"], h, pos, kc, vc,
                                              ring=False)
            x = x + a
            h = apply_norm(cfg, lp["ln2"], x)
            return x + mlp(cfg, lp["ffn"], h), {"k": kc, "v": vc}

        def outer(x, xs):
            gp_self, gp_cross, kc, vc, xk, xv = xs
            x, kv = jax.lax.scan(inner, x, (gp_self, kc, vc))
            lp = gp_cross
            h = apply_norm(cfg, lp["ln1"], x)
            q = attn.project_q(cfg, lp["xattn"], h, None)
            a = attn.sdpa(q, xk, xv, None).reshape(B, 1, cfg.q_dim)
            a = a @ lp["xattn"]["wo"].astype(x.dtype)
            x = x + jnp.tanh(lp["gate_attn"]).astype(x.dtype) * a
            h = apply_norm(cfg, lp["ln2"], x)
            f = mlp(cfg, lp["ffn"], h)
            x = x + jnp.tanh(lp["gate_ffn"]).astype(x.dtype) * f
            return x, kv

        x, ys = jax.lax.scan(outer, x, (params["groups"]["selfs"],
                                        params["groups"]["cross"],
                                        cache["k"], cache["v"],
                                        cache["xk"], cache["xv"]))
        cache = dict(cache)
        cache["k"], cache["v"] = ys["k"], ys["v"]
        cache["pos"] = pos + 1
        return self._logits(params, x), cache
