from repro.models.registry import (batch_extras, build_model, input_specs,
                                   make_batch)

__all__ = ["batch_extras", "build_model", "input_specs", "make_batch"]
