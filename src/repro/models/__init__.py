import repro._jax_compat  # noqa: F401  (sharding-invariant RNG)
from repro.models.registry import (batch_extras, build_model, input_specs,
                                   make_batch)

__all__ = ["batch_extras", "build_model", "input_specs", "make_batch"]
