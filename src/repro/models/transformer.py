"""Decoder-only LM covering the dense / MoE / hybrid (hymba) families.

Layers are grouped by structure signature (e.g. deepseek-moe's first dense
layer vs its 27 MoE layers) and each group runs under ``jax.lax.scan`` over
stacked params with per-layer ``jax.checkpoint`` — compile-time O(1) in depth,
activation memory O(L) in residuals only.  Decode uses full-length KV caches
for full-attention archs and ring buffers for sliding-window archs; hybrid
blocks additionally carry SSM states.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ssm as ssm_mod
from repro.models.common import (ParamSpec, apply_norm, cross_entropy_loss,
                                 norm_spec, pad_vocab, softcap, stack_specs,
                                 take_embedding, tree_get)
from repro.models.moe import moe_forward, moe_or_mlp_specs
from repro.models.mlp import mlp
from repro.parallel.act import shard_residual

REMAT_POLICIES = {
    "nothing": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.checkpoint_dots,
    "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
}


class DecoderOnlyLM:
    def __init__(self, cfg, *, max_cache_len: int = 0,
                 remat: str = "nothing", scan_layers: bool = True):
        self.cfg = cfg
        self.vp = pad_vocab(cfg.vocab_size)
        self.max_cache_len = max_cache_len or cfg.max_seq_len
        self.remat = remat
        self.scan_layers = scan_layers

    # ------------------------------------------------------------- structure
    def layer_groups(self) -> List[Tuple[int, bool]]:
        """[(n_layers, is_dense_mlp)] group split (moe first_k_dense)."""
        cfg = self.cfg
        if cfg.moe is not None and cfg.moe.first_k_dense:
            k = cfg.moe.first_k_dense
            return [(k, True), (cfg.n_layers - k, False)]
        return [(cfg.n_layers, cfg.moe is None)]

    def _block_specs(self, dense_mlp: bool) -> Dict[str, Any]:
        cfg = self.cfg
        s: Dict[str, Any] = {
            "ln1": norm_spec(cfg, cfg.d_model),
            "attn": attn.attn_specs(cfg),
            "ln2": norm_spec(cfg, cfg.d_model),
            "ffn": moe_or_mlp_specs(cfg, dense_mlp),
        }
        if cfg.family == "hybrid":
            s["ssm"] = ssm_mod.ssm_specs(cfg)
            s["out_norm_attn"] = norm_spec(cfg, cfg.d_model)
            s["out_norm_ssm"] = norm_spec(cfg, cfg.d_model)
        return s

    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        s: Dict[str, Any] = {
            "embed": ParamSpec((self.vp, cfg.d_model), ("vocab", "embed"),
                               "embed"),
            "final_norm": norm_spec(cfg, cfg.d_model),
        }
        if cfg.pos_embedding == "learned":
            s["pos_embed"] = ParamSpec((self.max_cache_len, cfg.d_model),
                                       (None, "embed"), "embed")
        if not cfg.tie_embeddings:
            s["lm_head"] = ParamSpec((cfg.d_model, self.vp),
                                     ("embed", "vocab"))
        for gi, (n, dense) in enumerate(self.layer_groups()):
            s[f"g{gi}"] = stack_specs(self._block_specs(dense), n)
        return s

    # ----------------------------------------------------------------- block
    def _window_eff(self, is_global):
        cfg = self.cfg
        if not cfg.window:
            return 0
        if is_global is None:
            return cfg.window
        return jnp.where(is_global, 0, cfg.window).astype(jnp.int32)

    def _train_mask(self, S: int, window_eff):
        qi = jnp.arange(S)[:, None]
        ki = jnp.arange(S)[None, :]
        m = ki <= qi
        if isinstance(window_eff, int):
            if window_eff:
                m &= ki > qi - window_eff
        else:
            m &= (window_eff == 0) | (ki > qi - window_eff)
        return m

    def _mixer(self, p, x, positions, window_eff, dense_mlp: bool,
               is_global):
        """Token mixer: attention (+ parallel SSM for hybrid)."""
        cfg = self.cfg
        h = apply_norm(cfg, p["ln1"], x)
        a = attn.attention(cfg, p["attn"], h, positions, None, causal=True,
                           window_eff=window_eff)
        if cfg.family == "hybrid":
            s = ssm_mod.ssm_forward(cfg, p["ssm"], h)
            a = 0.5 * (apply_norm(cfg, p["out_norm_attn"], a)
                       + apply_norm(cfg, p["out_norm_ssm"], s))
        return a

    def _ffn(self, p, x, dense_mlp: bool):
        cfg = self.cfg
        h = apply_norm(cfg, p["ln2"], x)
        if cfg.moe is not None and not dense_mlp:
            out, aux = moe_forward(cfg, p["ffn"], h)
            return out, aux
        return mlp(cfg, p["ffn"], h), jnp.zeros((), jnp.float32)

    def _block(self, p, x, positions, is_global, dense_mlp: bool):
        we = self._window_eff(is_global)
        x = shard_residual(x)
        # constrain the projection outputs themselves so the partitioner
        # reduce-scatters partial sums into the SP layout (half the wire of
        # all-reduce + slice)
        x = x + shard_residual(
            self._mixer(p, x, positions, we, dense_mlp, is_global))
        x = shard_residual(x)
        f, aux = self._ffn(p, x, dense_mlp)
        return shard_residual(x + shard_residual(f)), aux

    def _scan_group(self, gparams, x, positions, flags, dense_mlp: bool,
                    n_layers: int):
        """Run one layer group under scan + remat; returns (x, aux_sum)."""
        block = self._block

        def body(carry, xs):
            x, aux = carry
            lp, is_g = xs
            x, a = block(lp, x, positions, is_g, dense_mlp)
            return (x, aux + a), None

        body = jax.checkpoint(body, policy=REMAT_POLICIES[self.remat],
                              prevent_cse=False)
        if self.scan_layers:
            (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                       (gparams, flags))
        else:
            aux = jnp.zeros((), jnp.float32)
            for i in range(n_layers):
                (x, aux), _ = body((x, aux), (tree_get(gparams, i), flags[i]))
        return x, aux

    # --------------------------------------------------------------- forward
    def _embed(self, params, tokens, pos_offset=0):
        cfg = self.cfg
        x = take_embedding(params["embed"], tokens).astype(
            jnp.dtype(cfg.compute_dtype))
        if cfg.pos_embedding == "learned":
            S = tokens.shape[1]
            pe = jax.lax.dynamic_slice_in_dim(params["pos_embed"],
                                              pos_offset, S, 0)
            x = x + pe.astype(x.dtype)
        return shard_residual(x)

    def _global_flags(self, lo: int, hi: int):
        cfg = self.cfg
        return jnp.array([i in cfg.global_attn_layers
                          for i in range(lo, hi)], bool)

    def _run_layers(self, params, x, positions):
        aux = jnp.zeros((), jnp.float32)
        lo = 0
        for gi, (n, dense) in enumerate(self.layer_groups()):
            flags = self._global_flags(lo, lo + n)
            x, a = self._scan_group(params[f"g{gi}"], x, positions, flags,
                                    dense, n)
            aux = aux + a
            lo += n
        return x, aux

    def _logits(self, params, x):
        cfg = self.cfg
        x = apply_norm(cfg, params["final_norm"], x)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"]).astype(x.dtype)
        logits = x @ head
        logits = softcap(logits, cfg.logit_softcap)
        if self.vp != cfg.vocab_size:                 # mask padded vocab rows
            pad_mask = jnp.arange(self.vp) < cfg.vocab_size
            logits = jnp.where(pad_mask, logits, -1e30)
        return logits

    def forward(self, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        tokens = batch["tokens"]
        S = tokens.shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)
        x = self._embed(params, tokens)
        x, aux = self._run_layers(params, x, positions)
        return self._logits(params, x), aux

    def loss(self, params, batch):
        logits, aux = self.forward(params, batch)
        loss, metrics = cross_entropy_loss(
            logits, batch["labels"],
            z_loss_weight=getattr(self, "z_loss_weight", 1e-4))
        metrics["aux_loss"] = aux
        return loss + aux, metrics

    # ---------------------------------------------------------------- decode
    @property
    def _ring(self) -> bool:
        return bool(self.cfg.window) and self.max_cache_len > self.cfg.window

    @property
    def cache_window(self) -> int:
        return (min(self.cfg.window, self.max_cache_len) if self._ring
                else self.max_cache_len)

    def init_cache(self, batch: int, dtype=jnp.bfloat16) -> Dict[str, Any]:
        cfg = self.cfg
        W = self.cache_window
        cache: Dict[str, Any] = {
            "k": jnp.zeros((cfg.n_layers, batch, W, cfg.n_kv_heads,
                            cfg.head_dim), dtype),
            "v": jnp.zeros((cfg.n_layers, batch, W, cfg.n_kv_heads,
                            cfg.head_dim), dtype),
            "pos": jnp.zeros((), jnp.int32),
        }
        if cfg.family == "hybrid":
            st = ssm_mod.ssm_init_state(cfg, batch)
            cache["ssm"] = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), st)
        return cache

    def cache_axes(self):
        """Logical sharding axes mirroring init_cache's tree."""
        cfg = self.cfg
        axes = {
            "k": ("layers", "act_batch", "window", "kv_heads", None),
            "v": ("layers", "act_batch", "window", "kv_heads", None),
            "pos": (),
        }
        if cfg.family == "hybrid":
            axes["ssm"] = {
                "conv": ("layers", "act_batch", None, "ffn"),
                "h": ("layers", "act_batch", "ffn", None),
            }
        return axes

    def _stacked_layer_params(self, params):
        """View of all layers' params stacked along axis 0 (concat groups)."""
        groups = [params[f"g{gi}"]
                  for gi in range(len(self.layer_groups()))]
        if len(groups) == 1:
            return groups[0]
        # groups differ in ffn structure; decode handles them separately
        return groups

    def prefill(self, params, batch, cache=None):
        """Forward + cache population.  tokens: (B, S)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        if cache is None:
            cache = self.init_cache(B)
        W = self.cache_window
        positions = jnp.arange(S, dtype=jnp.int32)
        x = self._embed(params, tokens)

        k_all: List[jnp.ndarray] = []
        v_all: List[jnp.ndarray] = []
        ssm_states: List[Any] = []
        aux = jnp.zeros((), jnp.float32)
        lo = 0
        for gi, (n, dense) in enumerate(self.layer_groups()):
            gparams = params[f"g{gi}"]
            flags = self._global_flags(lo, lo + n)

            def body(carry, xs, dense=dense):
                x, aux = carry
                lp, is_g = xs
                h = apply_norm(cfg, lp["ln1"], x)
                q = attn.project_q(cfg, lp["attn"], h, positions)
                k, v = attn.project_kv(cfg, lp["attn"], h, positions)
                a = attn.sdpa_auto(q, k, v, causal=True,
                                   window_eff=self._window_eff(is_g))
                a = a.reshape(B, S, cfg.q_dim) @ lp["attn"]["wo"].astype(x.dtype)
                ys = {"k": k, "v": v}
                if cfg.family == "hybrid":
                    s_out, s_state = ssm_prefill(cfg, lp["ssm"], h)
                    a = 0.5 * (apply_norm(cfg, lp["out_norm_attn"], a)
                               + apply_norm(cfg, lp["out_norm_ssm"], s_out))
                    ys["ssm"] = s_state
                x = x + a
                f, a2 = self._ffn(lp, x, dense)
                return (x + f, aux + a2), ys

            body = jax.checkpoint(body, policy=REMAT_POLICIES[self.remat],
                                  prevent_cse=False, static_argnums=())
            (x, aux), ys = jax.lax.scan(body, (x, aux), (gparams, flags))
            k_all.append(ys["k"])
            v_all.append(ys["v"])
            if cfg.family == "hybrid":
                ssm_states.append(ys["ssm"])
            lo += n

        k_full = jnp.concatenate(k_all, 0) if len(k_all) > 1 else k_all[0]
        v_full = jnp.concatenate(v_all, 0) if len(v_all) > 1 else v_all[0]
        # write into (ring) cache: slot s holds the latest position p≡s (mod W)
        if S >= W:
            slot_pos = jnp.array([S - 1 - ((S - 1 - s) % W) for s in range(W)],
                                 jnp.int32)
            k_c = jnp.take(k_full, slot_pos, axis=2)
            v_c = jnp.take(v_full, slot_pos, axis=2)
        else:
            padw = ((0, 0), (0, 0), (0, W - S), (0, 0), (0, 0))
            k_c, v_c = jnp.pad(k_full, padw), jnp.pad(v_full, padw)
        cache = dict(cache)
        cache["k"] = k_c.astype(cache["k"].dtype)
        cache["v"] = v_c.astype(cache["v"].dtype)
        cache["pos"] = jnp.asarray(S, jnp.int32)
        if cfg.family == "hybrid":
            cache["ssm"] = (jax.tree_util.tree_map(
                lambda *xs: jnp.concatenate(xs, 0), *ssm_states)
                if len(ssm_states) > 1 else ssm_states[0])
        return self._logits(params, x[:, -1:]), cache

    def decode_step(self, params, tokens, cache):
        """tokens: (B, 1).  Returns (logits (B,1,V), cache)."""
        cfg = self.cfg
        B = tokens.shape[0]
        pos = cache["pos"]
        x = self._embed_decode(params, tokens, pos)
        ring = self._ring

        lo = 0
        new_k, new_v, new_ssm = [], [], []
        for gi, (n, dense) in enumerate(self.layer_groups()):
            gparams = params[f"g{gi}"]
            flags = self._global_flags(lo, lo + n)
            kc = jax.lax.dynamic_slice_in_dim(cache["k"], lo, n, 0)
            vc = jax.lax.dynamic_slice_in_dim(cache["v"], lo, n, 0)
            xs = [gparams, flags, kc, vc]
            if cfg.family == "hybrid":
                xs.append(jax.tree_util.tree_map(
                    lambda a: jax.lax.dynamic_slice_in_dim(a, lo, n, 0),
                    cache["ssm"]))

            def body(x, xs, dense=dense):
                if cfg.family == "hybrid":
                    lp, is_g, kc, vc, sst = xs
                else:
                    lp, is_g, kc, vc = xs
                    sst = None
                h = apply_norm(cfg, lp["ln1"], x)
                a, kc, vc = attn.decode_attention(
                    cfg, lp["attn"], h, pos, kc, vc, ring=ring,
                    is_global=is_g)
                ys = {"k": kc, "v": vc}
                if cfg.family == "hybrid":
                    s_out, sst = ssm_mod.ssm_decode_step(cfg, lp["ssm"], h, sst)
                    a = 0.5 * (apply_norm(cfg, lp["out_norm_attn"], a)
                               + apply_norm(cfg, lp["out_norm_ssm"], s_out))
                    ys["ssm"] = sst
                x = x + a
                f, _ = self._ffn(lp, x, dense)
                return x + f, ys

            x, ys = jax.lax.scan(body, x, tuple(xs))
            new_k.append(ys["k"])
            new_v.append(ys["v"])
            if cfg.family == "hybrid":
                new_ssm.append(ys["ssm"])
            lo += n

        cache = dict(cache)
        cache["k"] = (jnp.concatenate(new_k, 0) if len(new_k) > 1
                      else new_k[0])
        cache["v"] = (jnp.concatenate(new_v, 0) if len(new_v) > 1
                      else new_v[0])
        if cfg.family == "hybrid":
            cache["ssm"] = (jax.tree_util.tree_map(
                lambda *xs: jnp.concatenate(xs, 0), *new_ssm)
                if len(new_ssm) > 1 else new_ssm[0])
        cache["pos"] = pos + 1
        return self._logits(params, x), cache

    def _embed_decode(self, params, tokens, pos):
        cfg = self.cfg
        x = take_embedding(params["embed"], tokens).astype(
            jnp.dtype(cfg.compute_dtype))
        if cfg.pos_embedding == "learned":
            pe = jax.lax.dynamic_slice_in_dim(params["pos_embed"], pos, 1, 0)
            x = x + pe.astype(x.dtype)
        return x


def ssm_prefill(cfg, p, x):
    """SSM forward that also returns the decode state (conv tail + h)."""
    out = ssm_mod.ssm_forward(cfg, p, x)
    # recompute the conv input tail for the decode conv state
    xz = x @ p["in_proj"].astype(x.dtype)
    x_in = jnp.split(xz, 2, axis=-1)[0]
    K = cfg.ssm.d_conv
    tail = x_in[:, -(K - 1):]
    B, t = tail.shape[:2]
    if t < K - 1:
        tail = jnp.pad(tail, ((0, 0), (K - 1 - t, 0), (0, 0)))
    # final h: rerun the last chunk scan cheaply via full scan state
    h = _ssm_final_state(cfg, p, x)
    return out, {"conv": tail.astype(jnp.float32), "h": h}


def _ssm_final_state(cfg, p, x):
    from repro.models.ssm import (CHUNK, _causal_depthwise_conv, _discretize,
                                  _scan_chunk, ssm_dims)
    B, S, _ = x.shape
    di, _ = ssm_dims(cfg)
    xz = x @ p["in_proj"].astype(x.dtype)
    x_in = jnp.split(xz, 2, axis=-1)[0]
    x_c = jax.nn.silu(_causal_depthwise_conv(x_in, p["conv_w"], p["conv_b"]))
    L = min(CHUNK, S)
    n = -(-S // L)
    x_cp = jnp.pad(x_c, ((0, 0), (0, n * L - S), (0, 0)))
    # mask padded steps to identity updates so the final state is exact
    valid = (jnp.arange(n * L) < S).astype(jnp.float32)

    def step(h, inp):
        xc, m = inp
        dA, dBx, _ = _discretize(cfg, p, xc)
        dA = dA * m[None, :, None, None] + (1 - m)[None, :, None, None]
        dBx = dBx * m[None, :, None, None]
        _, h = _scan_chunk(dA, dBx, h)
        return h, None

    xs = (x_cp.reshape(B, n, L, di).transpose(1, 0, 2, 3),
          valid.reshape(n, L))
    h0 = jnp.zeros((B, di, cfg.ssm.d_state), jnp.float32)
    h, _ = jax.lax.scan(step, h0, xs)
    return h
