"""RWKV6 "Finch" model: token-shifted time-mix (data-dependent decay WKV) +
channel-mix blocks.  Decode state is O(1) in sequence length — the arch that
makes long_500k feasible.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import rwkv as rk
from repro.models.common import (ParamSpec, apply_norm, cross_entropy_loss,
                                 norm_spec, pad_vocab, stack_specs,
                                 take_embedding)
from repro.models.transformer import REMAT_POLICIES
from repro.parallel.act import shard_residual


class RWKV6LM:
    def __init__(self, cfg, *, max_cache_len: int = 0,
                 remat: str = "nothing", scan_layers: bool = True):
        self.cfg = cfg
        self.vp = pad_vocab(cfg.vocab_size)
        self.max_cache_len = max_cache_len or cfg.max_seq_len
        self.remat = remat

    def _block_specs(self):
        cfg = self.cfg
        return {"ln1": norm_spec(cfg, cfg.d_model),
                "tm": rk.time_mix_specs(cfg),
                "ln2": norm_spec(cfg, cfg.d_model),
                "cm": rk.channel_mix_specs(cfg)}

    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        return {
            "embed": ParamSpec((self.vp, cfg.d_model), ("vocab", "embed"),
                               "embed"),
            "ln0": norm_spec(cfg, cfg.d_model),     # rwkv post-embed norm
            "blocks": stack_specs(self._block_specs(), cfg.n_layers),
            "final_norm": norm_spec(cfg, cfg.d_model),
            "lm_head": ParamSpec((cfg.d_model, self.vp), ("embed", "vocab")),
        }

    # --------------------------------------------------------------- forward
    def forward(self, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        cfg = self.cfg
        tokens = batch["tokens"]
        x = take_embedding(params["embed"], tokens).astype(
            jnp.dtype(cfg.compute_dtype))
        x = apply_norm(cfg, params["ln0"], x)

        def body(x, lp):
            x = shard_residual(x)
            h = apply_norm(cfg, lp["ln1"], x)
            out, _, _ = rk.time_mix(cfg, lp["tm"], h)
            x = x + out
            h = apply_norm(cfg, lp["ln2"], x)
            out, _ = rk.channel_mix(cfg, lp["cm"], h)
            return x + out, None

        body = jax.checkpoint(body, policy=REMAT_POLICIES[self.remat],
                              prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["blocks"])
        return self._logits(params, x), jnp.zeros((), jnp.float32)

    def _logits(self, params, x):
        cfg = self.cfg
        x = apply_norm(cfg, params["final_norm"], x)
        logits = x @ params["lm_head"].astype(x.dtype)
        if self.vp != cfg.vocab_size:
            logits = jnp.where(jnp.arange(self.vp) < cfg.vocab_size,
                               logits, -1e30)
        return logits

    def loss(self, params, batch):
        logits, aux = self.forward(params, batch)
        loss, metrics = cross_entropy_loss(logits, batch["labels"])
        return loss, metrics

    # ---------------------------------------------------------------- decode
    def init_cache(self, batch: int, dtype=jnp.bfloat16) -> Dict[str, Any]:
        cfg = self.cfg
        H, D = rk.rwkv_dims(cfg)
        L, d = cfg.n_layers, cfg.d_model
        return {
            "tm_shift": jnp.zeros((L, batch, 1, d), dtype),
            "wkv": jnp.zeros((L, batch, H, D, D), jnp.float32),
            "cm_shift": jnp.zeros((L, batch, 1, d), dtype),
            "pos": jnp.zeros((), jnp.int32),
        }

    def cache_axes(self):
        sh = ("layers", "act_batch", None, "embed_dim")
        return {"tm_shift": sh, "cm_shift": sh,
                "wkv": ("layers", "act_batch", "heads", None, None),
                "pos": ()}

    def _run_with_state(self, params, tokens, cache):
        cfg = self.cfg
        x = take_embedding(params["embed"], tokens).astype(
            jnp.dtype(cfg.compute_dtype))
        x = apply_norm(cfg, params["ln0"], x)

        def body(x, xs):
            lp, tms, wkvs, cms = xs
            h = apply_norm(cfg, lp["ln1"], x)
            out, tms, wkvs = rk.time_mix(cfg, lp["tm"], h,
                                         shift_state=tms.astype(h.dtype),
                                         wkv_state=wkvs)
            x = x + out
            h = apply_norm(cfg, lp["ln2"], x)
            out, cms = rk.channel_mix(cfg, lp["cm"], h,
                                      shift_state=cms.astype(h.dtype))
            return x + out, {"tm_shift": tms, "wkv": wkvs, "cm_shift": cms}

        x, ys = jax.lax.scan(body, x, (params["blocks"], cache["tm_shift"],
                                       cache["wkv"], cache["cm_shift"]))
        new = dict(cache)
        new["tm_shift"] = ys["tm_shift"].astype(cache["tm_shift"].dtype)
        new["cm_shift"] = ys["cm_shift"].astype(cache["cm_shift"].dtype)
        new["wkv"] = ys["wkv"]
        return x, new

    def prefill(self, params, batch, cache=None):
        tokens = batch["tokens"]
        if cache is None:
            cache = self.init_cache(tokens.shape[0])
        x, cache = self._run_with_state(params, tokens, cache)
        cache["pos"] = jnp.asarray(tokens.shape[1], jnp.int32)
        return self._logits(params, x[:, -1:]), cache

    def decode_step(self, params, tokens, cache):
        x, cache = self._run_with_state(params, tokens, cache)
        cache["pos"] = cache["pos"] + 1
        return self._logits(params, x), cache
