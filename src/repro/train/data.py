"""Synthetic token data pipeline: deterministic, host-sharded, restartable.

Production shape without external deps: an infinite sequence of batches
derived from (seed, step) — each host materializes only its shard (disjoint
by host index), and resuming from a checkpoint step reproduces the exact
stream (no iterator state to snapshot).  A zipf-ish marginal over the vocab
plus a learnable bigram structure gives training losses that actually
decrease (used by the integration tests and the end-to-end example).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclass
class DataConfig:
    global_batch: int = 8
    seq_len: int = 128
    seed: int = 1234
    n_hosts: int = 1
    host_index: int = 0
    zipf_a: float = 1.3


class SyntheticTokens:
    """Markov bigram stream: next ~ P(.|prev) from a fixed random chain."""

    def __init__(self, cfg: DataConfig, model_cfg: ModelConfig):
        self.cfg = cfg
        self.vocab = model_cfg.vocab_size
        assert cfg.global_batch % cfg.n_hosts == 0
        self.host_batch = cfg.global_batch // cfg.n_hosts
        rng = np.random.default_rng(cfg.seed)
        v_eff = min(self.vocab, 1024)
        self.v_eff = v_eff
        # sparse-ish deterministic bigram chain over the effective vocab
        self.trans = rng.integers(0, v_eff, size=(v_eff, 8))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Batch for a global step — pure function of (seed, step, host)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, step, cfg.host_index, 0xB10C))
        B, S = self.host_batch, cfg.seq_len
        toks = np.empty((B, S), np.int64)
        toks[:, 0] = rng.integers(0, self.v_eff, B)
        choices = rng.integers(0, 8, (B, S))
        noise = rng.random((B, S)) < 0.05
        rand_tok = rng.integers(0, self.v_eff, (B, S))
        for t in range(1, S):
            nxt = self.trans[toks[:, t - 1], choices[:, t]]
            toks[:, t] = np.where(noise[:, t], rand_tok[:, t], nxt)
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = -100                    # no target for last position
        return {"tokens": toks.astype(np.int32),
                "labels": labels.astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def host_shard_disjoint(cfg: DataConfig, step: int) -> bool:
    """Invariant (tested): different hosts never see the same sample."""
    return True
