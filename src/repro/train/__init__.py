from repro.train.checkpoint import CheckpointManager
from repro.train.data import DataConfig, SyntheticTokens
from repro.train.fault import ElasticPlan, Watchdog, WatchdogConfig
from repro.train.optimizer import (AdamWState, adamw_update, init_state,
                                   lr_schedule)

__all__ = ["CheckpointManager", "DataConfig", "SyntheticTokens",
           "ElasticPlan", "Watchdog", "WatchdogConfig", "AdamWState",
           "adamw_update", "init_state", "lr_schedule", "LitSiliconHook",
           "Trainer", "TrainerConfig"]


def __getattr__(name):
    # lazy: train_loop imports parallel.fsdp which imports train.optimizer
    if name in ("LitSiliconHook", "Trainer", "TrainerConfig"):
        from repro.train import train_loop
        return getattr(train_loop, name)
    raise AttributeError(name)
