"""Fault tolerance: watchdog + elastic restart plan.

The paper's technique is itself a straggler-mitigation service; this module
adds the rest of the production story:

  * ``Watchdog`` — NaN/inf loss or gradient blowup triggers a rollback to
    the last checkpoint (with an LR backoff option); step-time stall
    detection flags slow/hung steps (on a cluster: escalate to the job
    controller, which drains the node — the thermal kind of straggle is
    instead *tuned around* by the PowerManager).
  * ``ElasticPlan`` — given the surviving device count after a failure,
    recompute the largest usable (data, model) mesh and the per-host batch;
    CheckpointManager.restore re-places every leaf with the new mesh's
    shardings, so resuming on fewer (or more) hosts is just restore+go.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


@dataclass
class WatchdogConfig:
    stall_factor: float = 5.0           # step slower than median x this
    max_rollbacks: int = 3
    lr_backoff: float = 0.5             # multiply LR on rollback
    window: int = 50


class Watchdog:
    def __init__(self, cfg: WatchdogConfig = WatchdogConfig()):
        self.cfg = cfg
        self.step_times: List[float] = []
        self.rollbacks = 0
        self.stalls = 0
        self._t0: Optional[float] = None

    def start_step(self) -> None:
        self._t0 = time.monotonic()

    def end_step(self, loss: float, grad_norm: float) -> str:
        """Returns 'ok' | 'stall' | 'rollback'."""
        dt = time.monotonic() - (self._t0 or time.monotonic())
        verdict = "ok"
        if self.step_times:
            med = float(np.median(self.step_times[-self.cfg.window:]))
            if med > 0 and dt > self.cfg.stall_factor * med:
                self.stalls += 1
                verdict = "stall"
        self.step_times.append(dt)
        if not math.isfinite(loss) or not math.isfinite(grad_norm):
            self.rollbacks += 1
            if self.rollbacks > self.cfg.max_rollbacks:
                raise RuntimeError(
                    f"watchdog: {self.rollbacks} rollbacks exceeded budget")
            verdict = "rollback"
        return verdict


@dataclass
class ElasticPlan:
    """Mesh/batch replan after a membership change."""

    n_devices: int
    model_parallel: int                 # keep TP extent (weights layout)
    global_batch: int

    def mesh_shape(self) -> tuple:
        assert self.n_devices % self.model_parallel == 0, \
            "surviving devices must still divide by the TP extent"
        data = self.n_devices // self.model_parallel
        return (data, self.model_parallel)

    def batch_per_replica(self) -> int:
        data = self.n_devices // self.model_parallel
        if self.global_batch % data:
            # keep the global batch: pad replicas (standard practice is to
            # round the batch; we keep semantics and report the remainder)
            return -(-self.global_batch // data)
        return self.global_batch // data

    @staticmethod
    def after_failure(n_devices: int, failed: int, model_parallel: int,
                      global_batch: int) -> "ElasticPlan":
        """Drop whole model-parallel groups containing failed chips."""
        groups = (n_devices - failed) // model_parallel
        if groups < 1:
            raise RuntimeError("not enough devices for one model replica")
        return ElasticPlan(groups * model_parallel, model_parallel,
                           global_batch)
