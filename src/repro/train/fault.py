"""Fault tolerance: watchdog + elastic restart plan.

The paper's technique is itself a straggler-mitigation service; this module
adds the rest of the production story:

  * ``Watchdog`` — NaN/inf loss or gradient blowup triggers a rollback to
    the last checkpoint (with an LR backoff option); step-time stall
    detection flags slow/hung steps (on a cluster: escalate to the job
    controller, which drains the node — the thermal kind of straggle is
    instead *tuned around* by the PowerManager).
  * ``ElasticPlan`` — given the surviving device count after a failure,
    recompute the largest usable (data, model) mesh and the per-host batch;
    CheckpointManager.restore re-places every leaf with the new mesh's
    shardings, so resuming on fewer (or more) hosts is just restore+go.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


@dataclass
class WatchdogConfig:
    stall_factor: float = 5.0           # step slower than median x this
    max_rollbacks: int = 3
    lr_backoff: float = 0.5             # multiply LR on rollback
    window: int = 50


class Watchdog:
    """Step-health monitor.  Timing is injected, never defaulted: pass
    ``clock`` (a ``time.monotonic``-shaped callable) at construction, or
    hand ``end_step`` an explicit ``dt`` in simulated seconds.  With
    neither, ``end_step`` raises — a watchdog that silently binds the
    wall clock would make an argless construction nondeterministic
    (train_loop passes ``clock=time.monotonic`` explicitly for real
    runs)."""

    def __init__(self, cfg: WatchdogConfig = WatchdogConfig(), clock=None):
        self.cfg = cfg
        self.clock = clock
        self.step_times: List[float] = []
        self.rollbacks = 0
        self.stalls = 0
        self._t0: Optional[float] = None

    def start_step(self) -> None:
        self._t0 = self.clock() if self.clock is not None else None

    def end_step(self, loss: float, grad_norm: float,
                 dt: Optional[float] = None) -> str:
        """Returns 'ok' | 'stall' | 'rollback'.  ``dt`` overrides the
        measured step duration (simulated time drives the stall check)."""
        if dt is None:
            if self.clock is None:
                raise ValueError(
                    "Watchdog has no clock: pass dt= to end_step or "
                    "construct with clock= (e.g. time.monotonic)")
            dt = self.clock() - (self._t0 or self.clock())
        verdict = "ok"
        if self.step_times:
            med = float(np.median(self.step_times[-self.cfg.window:]))
            if med > 0 and dt > self.cfg.stall_factor * med:
                self.stalls += 1
                verdict = "stall"
        self.step_times.append(dt)
        if not math.isfinite(loss) or not math.isfinite(grad_norm):
            self.rollbacks += 1
            if self.rollbacks > self.cfg.max_rollbacks:
                raise RuntimeError(
                    f"watchdog: {self.rollbacks} rollbacks exceeded budget")
            verdict = "rollback"
        return verdict


@dataclass
class ElasticPlan:
    """Mesh/batch replan after a membership change."""

    n_devices: int
    model_parallel: int                 # keep TP extent (weights layout)
    global_batch: int

    def mesh_shape(self) -> tuple:
        if self.n_devices % self.model_parallel:
            raise ValueError(
                f"{self.n_devices} surviving devices do not divide by the "
                f"TP extent {self.model_parallel}")
        data = self.n_devices // self.model_parallel
        return (data, self.model_parallel)

    def batch_per_replica(self) -> int:
        """Per-replica batch, rounded *up* when the global batch does not
        divide the data extent (the global batch is kept; replicas pad).
        ``batch_padding`` reports the padded remainder."""
        data = self.mesh_shape()[0]
        return -(-self.global_batch // data)

    def batch_padding(self) -> int:
        """Padded samples per iteration: how many of the
        ``batch_per_replica * data`` slots carry no real sample (0 when the
        global batch divides evenly) — wasted compute the goodput metric
        should not credit."""
        data = self.mesh_shape()[0]
        return self.batch_per_replica() * data - self.global_batch

    @staticmethod
    def after_failure(n_devices: int, failed: int, model_parallel: int,
                      global_batch: int) -> "ElasticPlan":
        """Drop whole model-parallel groups containing failed chips."""
        groups = (n_devices - failed) // model_parallel
        if groups < 1:
            raise RuntimeError("not enough devices for one model replica")
        return ElasticPlan(groups * model_parallel, model_parallel,
                           global_batch)
