"""Trainer: the end-to-end loop wiring every substrate together.

hooks: checkpointing (atomic/async), watchdog rollback, and — the paper's
contribution as a first-class runtime service — the Lit Silicon co-sim hook:
each real training step advances the thermal/C3 node simulation one
iteration and feeds its trace to the PowerManager, which tunes per-device
power caps online (on real hardware the trace would come from the profiler
hook instead; nothing else changes).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import (ModelConfig, ParallelConfig, TrainConfig)
from repro.core.backends import SimBackend
from repro.core.c3sim import NodeSim, SimConfig
from repro.core.manager import ManagerConfig, PowerManager
from repro.core.thermal import PRESETS
from repro.core.workload import fsdp_llm_iteration
from repro.models.registry import build_model
from repro.parallel.fsdp import (TrainState, build_train_step,
                                 init_train_state)
from repro.parallel.sharding import ShardingRules
from repro.train.checkpoint import CheckpointManager
from repro.train.data import DataConfig, SyntheticTokens
from repro.train.fault import Watchdog, WatchdogConfig


class LitSiliconHook:
    """Co-simulation hook: real JAX step + simulated node physics."""

    def __init__(self, model_cfg: ModelConfig, manager_cfg: ManagerConfig,
                 preset: str = "mi300x", n_devices: int = 8,
                 sim: Optional[SimConfig] = None, seed: int = 0):
        wl = fsdp_llm_iteration(model_cfg, batch=2, seq=min(
            4096, model_cfg.max_seq_len), n_shards=n_devices)
        self.node = NodeSim(wl, PRESETS[preset], sim or SimConfig(seed=seed),
                            n_devices, seed=seed)
        self.backend = SimBackend(self.node)
        self.manager = PowerManager(self.backend, manager_cfg)

    def __call__(self, step: int, metrics: Dict[str, Any], trainer) -> None:
        trace = self.backend.run_iteration()
        self.manager.on_iteration(step, trace)
        h = self.node.history[-1]
        metrics["sim/throughput"] = h["throughput"]
        metrics["sim/node_power"] = float(np.sum(h["power"]))
        metrics["sim/freq_min"] = float(np.min(h["freq"]))
        metrics["sim/freq_max"] = float(np.max(h["freq"]))


@dataclass
class TrainerConfig:
    model: ModelConfig
    train: TrainConfig = field(default_factory=TrainConfig)
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    data: DataConfig = field(default_factory=DataConfig)
    log_every: int = 10


class Trainer:
    def __init__(self, cfg: TrainerConfig, mesh=None,
                 hooks: Optional[List[Callable]] = None):
        self.cfg = cfg
        self.mesh = mesh or jax.sharding.Mesh(
            np.array(jax.devices()).reshape(-1, 1), ("data", "model"))
        self.model = build_model(cfg.model, remat=cfg.parallel.remat_policy,
                                 scan_layers=cfg.parallel.scan_layers)
        self.rules = ShardingRules(self.mesh, cfg.model, cfg.parallel)
        self.step_fn, self.state_shardings = build_train_step(
            self.model, cfg.train, self.rules, cfg.parallel)
        self.data = SyntheticTokens(cfg.data, cfg.model)
        self.ckpt = CheckpointManager(cfg.train.checkpoint_dir,
                                      keep=cfg.train.keep_checkpoints)
        self.watchdog = Watchdog(WatchdogConfig(), clock=time.monotonic)
        self.hooks = hooks or []
        self.metrics_log: List[Dict[str, Any]] = []
        self.state: Optional[TrainState] = None
        self.step = 0

    # ------------------------------------------------------------------ init
    def init_or_restore(self) -> None:
        latest = self.ckpt.latest_step()
        if latest is not None:
            like = jax.eval_shape(
                lambda: init_train_state(self.model, self.rules,
                                         self.cfg.parallel))
            self.state, manifest = self.ckpt.restore(
                like, shardings=self.state_shardings)
            self.step = manifest["step"]
        else:
            self.state = init_train_state(self.model, self.rules,
                                          self.cfg.parallel,
                                          seed=self.cfg.train.seed)
            self.step = 0

    # ------------------------------------------------------------------ run
    def run(self, n_steps: int) -> List[Dict[str, Any]]:
        if self.state is None:
            self.init_or_restore()
        mesh = self.mesh
        last_ckpt_step = self.step
        with mesh:
            for _ in range(n_steps):
                batch = {k: jax.numpy.asarray(v) for k, v in
                         self.data.batch_at(self.step).items()}
                self.watchdog.start_step()
                self.state, metrics = self.step_fn(self.state, batch)
                loss = float(metrics["loss"])
                gnorm = float(metrics["grad_norm"])
                verdict = self.watchdog.end_step(loss, gnorm)
                if verdict == "rollback":
                    self._rollback()
                    continue
                metrics = {k: (float(v) if hasattr(v, "item") else v)
                           for k, v in metrics.items()}
                metrics["step"] = self.step
                for hook in self.hooks:
                    hook(self.step, metrics, self)
                self.metrics_log.append(metrics)
                self.step += 1
                if (self.cfg.train.checkpoint_every
                        and self.step % self.cfg.train.checkpoint_every == 0):
                    self.save()
                    last_ckpt_step = self.step
        return self.metrics_log

    def save(self) -> str:
        return self.ckpt.save(self.step, self.state,
                              extra={"model": self.cfg.model.name})

    def _rollback(self) -> None:
        latest = self.ckpt.latest_step()
        if latest is None:
            # no checkpoint yet: re-init (counts against watchdog budget)
            self.state = init_train_state(self.model, self.rules,
                                          self.cfg.parallel,
                                          seed=self.cfg.train.seed + 1)
            self.step = 0
            return
        like = jax.eval_shape(
            lambda: init_train_state(self.model, self.rules,
                                     self.cfg.parallel))
        self.state, manifest = self.ckpt.restore(
            like, shardings=self.state_shardings)
        self.step = manifest["step"]
