"""Fault-tolerant sharded checkpointing.

Layout:  <dir>/step_<N>/
           manifest.json       — tree structure, shapes, dtypes, step, config
           shard_<host>.npz    — this host's param/optimizer leaves
         <dir>/LATEST          — atomically updated pointer

Guarantees:
  * atomicity — written to ``.tmp-step_<N>`` then ``os.replace``d; a crash
    mid-write never corrupts the previous checkpoint;
  * async     — the device->host copy is synchronous (cheap) but file I/O
    runs on a writer thread so the train loop isn't blocked;
  * elastic restore — leaves are saved unsharded (gathered) and re-placed
    with the *current* mesh's NamedShardings on restore, so the data-parallel
    extent can change between runs (node failure / resize);
  * retention — keep_checkpoints newest are retained.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

SEP = "/"


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        keys = []
        for p in path:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
            elif hasattr(p, "name"):
                keys.append(str(p.name))
            else:
                keys.append(str(p))
        out.append((SEP.join(keys), leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, extra: Optional[Dict] = None) -> str:
        self.wait()                       # one in-flight write at a time
        flat = _flatten_with_paths(tree)
        # gather to host memory now (cheap on CPU; device->host on TPU).
        # npz has no bfloat16: store as uint16 bit pattern, record dtype.
        arrays: Dict[str, np.ndarray] = {}
        dtypes: Dict[str, str] = {}
        for k, v in flat:
            a = np.asarray(v)
            dtypes[k] = str(jax.numpy.asarray(v).dtype)
            if a.dtype.kind == "V":       # bfloat16 -> raw bits
                a = a.view(np.uint16)
            arrays[k] = a
        manifest = {
            "step": step,
            "keys": [k for k, _ in flat],
            "shapes": {k: list(np.shape(v)) for k, v in flat},
            "dtypes": dtypes,
            "extra": extra or {},
        }

        def write():
            final = os.path.join(self.dir, f"step_{step:08d}")
            tmp = os.path.join(self.dir, f".tmp-step_{step:08d}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "shard_0.npz"),
                     **{k.replace("/", "|"): v for k, v in arrays.items()})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f, sort_keys=True, allow_nan=False)
            shutil.rmtree(final, ignore_errors=True)
            os.replace(tmp, final)
            lat_tmp = os.path.join(self.dir, ".LATEST.tmp")
            with open(lat_tmp, "w") as f:
                f.write(os.path.basename(final))
            os.replace(lat_tmp, os.path.join(self.dir, "LATEST"))
            self._gc()
            self._clean_stale_tmp()

        if self.async_write:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()
        return os.path.join(self.dir, f"step_{step:08d}")

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_"))
        for d in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    def _clean_stale_tmp(self) -> None:
        """Remove ``.tmp-step_*`` leftovers from writers that crashed
        mid-save (the completed ``os.replace`` means none belong to us)."""
        for d in sorted(os.listdir(self.dir)):
            if d.startswith(".tmp-step_"):
                shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        lat = os.path.join(self.dir, "LATEST")
        if not os.path.exists(lat):
            return None
        with open(lat) as f:
            name = f.read().strip()
        if not os.path.isdir(os.path.join(self.dir, name)):
            return None
        return int(name.split("_")[1])

    def restore(self, tree_like, step: Optional[int] = None,
                shardings=None) -> Tuple[Any, Dict]:
        """Restore into the structure of ``tree_like``; place each leaf with
        the given shardings tree (elastic resharding) if provided."""
        self.wait()
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint under {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "shard_0.npz"))
        arrays = {k.replace("|", "/"): data[k] for k in data.files}

        flat = _flatten_with_paths(tree_like)
        treedef = jax.tree_util.tree_structure(tree_like)
        shard_flat = (None if shardings is None
                      else [s for _, s in _flatten_with_paths(shardings)])
        leaves = []
        for i, (key, like) in enumerate(flat):
            if key not in arrays:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = arrays[key]
            dt = manifest["dtypes"].get(key, str(arr.dtype))
            if dt == "bfloat16" and arr.dtype == np.uint16:
                arr = arr.view(jax.numpy.bfloat16.dtype)
            if shard_flat is not None:
                leaves.append(jax.device_put(arr, shard_flat[i]))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves), manifest
