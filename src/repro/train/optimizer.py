"""AdamW in pure JAX: fp32 master params & moments (ZeRO-sharded like the
params), global-norm clipping, warmup+cosine schedule, weight decay.

State layout mirrors the param tree, so the same ShardingRules spec trees
apply (exp_avg/exp_avg_sq inherit each param's sharding) — that is ZeRO
stage-2/3 for free under pjit.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamWState(NamedTuple):
    step: jnp.ndarray
    exp_avg: Any
    exp_avg_sq: Any


def init_state(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), exp_avg=zeros,
                      exp_avg_sq=jax.tree_util.tree_map(jnp.copy, zeros))


def lr_schedule(cfg: TrainConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 \
        * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(cfg: TrainConfig, params, grads,
                 state: AdamWState) -> Tuple[Any, AdamWState, Dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2, eps = cfg.beta1, cfg.beta2, cfg.eps
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / c1
        vh = v / c2
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (mh / (jnp.sqrt(vh) + eps)
                          + cfg.weight_decay * p32)
        return p32.astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.exp_avg)
    flat_v = jax.tree_util.tree_leaves(state.exp_avg_sq)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics
