"""JAX version-compat knobs, applied when a jax-facing subpackage loads.

``jax_threefry_partitionable`` defaults to False on the 0.4.x line, which
makes ``jax.random`` draws inside jit depend on the output sharding — a
(2, 4)-mesh initialization then differs from single-device, breaking the
sharded-equals-reference train tests.  Newer jax defaults it to True
(sharding-invariant random bits); opt in explicitly so every supported
version behaves the same.
"""
from __future__ import annotations

import jax

try:
    jax.config.update("jax_threefry_partitionable", True)
except Exception:                      # unknown option on a future release
    pass
