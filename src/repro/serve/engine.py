"""ServingFleet: continuous batching on top of the cluster simulator.

Composition (docs/serving.md): an embedded :class:`ClusterSim` provides N
thermally-independent `NodeSim`s (presets, hot devices, churn — the same
construction every training scenario uses), but the serving loop replaces
the training step's *global barrier* with **asynchronous per-node clocks**:
inference replicas don't all-reduce, so each node advances by its own
``t_iter`` and commits thermals over exactly that interval.  A thermal
straggler therefore doesn't stretch its peers — it falls behind its own
queue, which is the serving-shaped Lit Silicon coupling: heat → DVFS
throttle → longer engine steps → backlog → TTFT tail inflation.

Per engine round, per node:

  1. arrivals with ``t_arrival <= clock`` are routed (static round-robin
     by request id) into the node's `ContinuousBatcher` queue, and free
     slots are refilled FIFO;
  2. the node runs one C3 iteration (vector/jax engines batch all nodes
     into one pass, exactly as `ClusterSim` does);
  3. the batcher advances every slot one step (prefill chunk or one
     decode token), completions are recorded, and the node commits
     thermals over its own ``t_iter``;
  4. the per-node *tail signal* is refreshed: max(recent-TTFT quantile,
     head-of-line first-token age) — what the ``tail-latency`` manager
     objective consumes via ``FleetPowerManager.on_serve_iteration``.

Determinism: the request trace is generated up front from ``[seed, k]``
child seeds (traffic.py) and never touches the simulator RNG streams, so
a serve run is reproducible per engine exactly like a training run.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.cluster import ClusterConfig, ClusterSim
from repro.core.c3sim import SimConfig
from repro.core.thermal import DevicePreset
from repro.core.workload import Workload
from repro.serve.batcher import ContinuousBatcher
from repro.serve.metrics import slo_summary
from repro.serve.traffic import RequestTrace, generate_requests
from repro.telemetry.collector import RequestRecord

__all__ = ["ServingFleet", "ServeReport"]


@dataclass
class ServeReport:
    """What a serving run hands back: the full request population (the
    offered set, completed + flushed-incomplete), the SLO summary, and
    the per-node clocks the rates were normalized by."""

    records: List[RequestRecord] = field(default_factory=list)
    summary: Dict[str, float] = field(default_factory=dict)
    clocks: Optional[np.ndarray] = None     # (N,) final node clocks (s)
    t_fleet_s: float = 0.0                  # mean final clock
    rounds: int = 0
    n_generated: int = 0                    # trace length (incl. unarrived)
    round_history: List[dict] = field(default_factory=list)


class ServingFleet:
    """N serving replicas with continuous batching over C3Sim nodes."""

    def __init__(self, workload: Workload, preset: DevicePreset,
                 sim_cfg: SimConfig, cluster_cfg: ClusterConfig,
                 serve_spec, devices_per_node: int = 8, seed: int = 0):
        self.cluster = ClusterSim(workload, preset, sim_cfg, cluster_cfg,
                                  devices_per_node=devices_per_node,
                                  seed=seed)
        self.spec = serve_spec
        self.N = self.cluster.N
        self.trace: RequestTrace = generate_requests(serve_spec, seed)
        self.batchers = [ContinuousBatcher(slots=serve_spec.batch_slots,
                                           prefill_chunk=serve_spec.prefill_chunk,
                                           node=n) for n in range(self.N)]
        # static round-robin router: request k serves on node k mod N —
        # deterministic and fleet-size-independent per request id
        self._pending: List[deque] = [deque() for _ in range(self.N)]
        for r in self.trace.requests:
            self._pending[r.rid % self.N].append(r)
        self.clock = np.zeros(self.N)
        self.records: List[RequestRecord] = []
        self.collector = None

    # ------------------------------------------------------------- plumbing
    def attach_collector(self, collector) -> None:
        """Attach telemetry: per-node commit hooks + per-round fleet rows
        (``on_serve_round``: async replicas have no barrier, so the fleet
        row carries the round span, the observed per-node intervals and
        the tail signal) + per-request records."""
        collector.attach_cluster(self.cluster)
        self.collector = collector

    def _tail_signal(self, ttft_windows: List[deque], quantile: float,
                     window_s: float) -> np.ndarray:
        """Per-node tail signal: the larger of the recent completed-TTFT
        quantile and the head-of-line first-token age.  The quantile sees
        inflation that already happened; the head age sees a backlog that
        hasn't produced (slow) completions *yet* — together the signal
        rises as soon as a node falls behind and stays up until its queue
        actually drains.  The window is *time*-based (first tokens within
        the node's last ``window_s`` seconds): a count-based window goes
        stale at low per-node completion rates and makes the controller
        chase tails that drained long ago."""
        sig = np.zeros(self.N)
        for n in range(self.N):
            w = ttft_windows[n]
            cutoff = self.clock[n] - window_s
            while w and w[0][0] < cutoff:
                w.popleft()
            q = (float(np.quantile([t for _, t in w], quantile))
                 if w else 0.0)
            sig[n] = max(q, self.batchers[n].oldest_unserved_age(
                self.clock[n]))
        return sig

    # ------------------------------------------------------------------ run
    def run(self, rounds: int, manager=None,
            tune_after: Optional[int] = None) -> ServeReport:
        """Drive ``rounds`` engine rounds; with a `FleetPowerManager`,
        enable it from ``tune_after`` (default: halfway, the same
        convention as the training closed loop)."""
        tune_after = rounds // 2 if tune_after is None else tune_after
        tq, tw_s = 0.95, 10.0
        if manager is not None:
            tq = getattr(manager.cfg, "tail_quantile", tq)
            tw_s = getattr(manager.cfg, "tail_window_s", tw_s)
        ttft_windows = [deque() for _ in range(self.N)]
        rep = ServeReport(rounds=rounds, n_generated=len(self.trace))
        for r in range(rounds):
            for n in range(self.N):
                pend, b = self._pending[n], self.batchers[n]
                while pend and pend[0].t_arrival <= self.clock[n]:
                    b.enqueue(pend.popleft())
                b.admit(self.clock[n])
            traces = self.cluster._run_nodes()
            for n, (node, tr) in enumerate(zip(self.cluster.nodes, traces)):
                t_end = float(self.clock[n] + tr.t_iter)
                b = self.batchers[n]
                for rec in b.step(t_end):
                    self.records.append(rec)
                    if self.collector is not None:
                        self.collector.on_request(rec)
                ttft_windows[n].extend(b.first_token_events)
                b.first_token_events.clear()
                # async replicas: commit over the node's own interval —
                # no barrier stretching, no active wait
                node.commit(tr, t_interval=tr.t_iter)
                self.clock[n] = t_end
            sig = self._tail_signal(ttft_windows, tq, tw_s)
            if self.collector is not None:
                self.collector.on_serve_round(
                    r, [float(tr.t_iter) for tr in traces], sig,
                    topology=self.cluster.topology.name)
            if manager is not None and r >= tune_after:
                manager.on_serve_iteration(r, traces, tail_signal=sig)
            rep.round_history.append({
                "round": r,
                "t_local": [float(tr.t_iter) for tr in traces],
                "clock": self.clock.copy(),
                "active": [b.n_active for b in self.batchers],
                "queued": [b.n_queued for b in self.batchers],
                "tail_signal": sig,
            })
        # flush unfinished work so the records are the full offered set
        for b in self.batchers:
            for rec in b.flush():
                self.records.append(rec)
                if self.collector is not None:
                    self.collector.on_request(rec)
        rep.records = list(self.records)
        rep.clocks = self.clock.copy()
        rep.t_fleet_s = float(self.clock.mean())
        rep.summary = slo_summary(
            rep.records, ttft_deadline_s=self.spec.ttft_deadline_s,
            tpot_deadline_s=self.spec.tpot_deadline_s,
            t_elapsed_s=rep.t_fleet_s, n_nodes=self.N)
        if self.collector is not None:
            # everything replay_slo needs to recompute the summary offline
            self.collector.meta["serve"] = {
                "process": self.spec.process,
                "rate_rps": self.trace.rate_rps,
                "horizon_s": self.spec.horizon_s,
                "ttft_deadline_s": self.spec.ttft_deadline_s,
                "tpot_deadline_s": self.spec.tpot_deadline_s,
                "t_fleet_s": rep.t_fleet_s,
                "n_nodes": self.N,
                "batch_slots": self.spec.batch_slots,
                "prefill_chunk": self.spec.prefill_chunk,
            }
        return rep
