"""Request-arrival traces: the production-traffic front end of serve/*.

A serving study starts from *who shows up when*: this module turns a
:class:`~repro.api.spec.ServeSpec`-shaped config into a deterministic
sequence of :class:`Request` records (arrival time, prompt length, output
length).  Two arrival processes:

  * ``poisson`` — memoryless arrivals at a constant mean rate, the
    steady-state load model;
  * ``diurnal`` — a sinusoidally modulated rate (peak/trough traffic over
    a day compressed to ``diurnal_period_s``), so queues build and drain
    within one run.

Scale is expressed either directly (``rate_rps``) or through the
millions-of-users knob (``users_m`` x ``user_req_per_day`` spread over a
day) — the latter is how a "serves millions of users" target becomes a
requests-per-second number.

Determinism contract (property-tested in tests/test_serve.py): request
``k`` draws *all* of its randomness from its own child generator seeded
``[seed, k]`` (the same convention api/sweep.py uses for sample children).
Arrival time is the cumulative sum of per-request gaps, so truncating the
trace (smaller ``max_requests`` / shorter ``horizon_s``) yields a byte-
identical *prefix* of the longer trace, and the trace never consumes the
simulator's RNG streams — generation is identical under every C3 engine.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List

import numpy as np

__all__ = ["Request", "RequestTrace", "generate_requests",
           "ARRIVAL_PROCESSES"]

ARRIVAL_PROCESSES = ("poisson", "diurnal")


@dataclass
class Request:
    """One inference request of the trace."""

    rid: int                        # trace-order id (also the child seed)
    t_arrival: float                # s since trace start
    prompt_len: int                 # tokens to prefill
    output_len: int                 # tokens to decode (>= 1)


@dataclass
class RequestTrace:
    """A generated arrival trace plus the knobs that produced it."""

    requests: List[Request] = field(default_factory=list)
    process: str = "poisson"
    rate_rps: float = 0.0           # effective mean rate used
    horizon_s: float = 0.0
    seed: int = 0

    def __len__(self) -> int:
        return len(self.requests)

    @property
    def total_prompt_tokens(self) -> int:
        return int(sum(r.prompt_len for r in self.requests))

    @property
    def total_output_tokens(self) -> int:
        return int(sum(r.output_len for r in self.requests))


def _diurnal_rate(base: float, amp: float, period_s: float,
                  t: float) -> float:
    """Instantaneous arrival rate at time ``t`` under the diurnal model:
    a full peak/trough swing of relative amplitude ``amp`` per period,
    starting at the mean and rising (so short horizons see the ramp)."""
    return base * (1.0 + amp * np.sin(2.0 * np.pi * t / period_s))


def _lognormal_len(rng: np.random.Generator, mean: float, sigma: float,
                   lo: int, hi: int) -> int:
    """A lognormal token count with the given *mean* (mu is solved from
    mean and sigma), clipped to [lo, hi]."""
    mu = np.log(mean) - 0.5 * sigma * sigma
    return int(np.clip(round(float(rng.lognormal(mu, sigma))), lo, hi))


def generate_requests(spec, seed: int) -> RequestTrace:
    """Materialize the arrival trace for ``spec`` (a ServeSpec).

    Request ``k``'s gap-to-previous, prompt length and output length all
    come from ``np.random.default_rng([seed, k])`` — the prefix-stable
    child-seeding convention (docs/serving.md).
    """
    if spec.process not in ARRIVAL_PROCESSES:
        raise ValueError(f"unknown arrival process {spec.process!r} "
                         f"(expected one of {ARRIVAL_PROCESSES})")
    rate = spec.arrival_rate()
    if rate <= 0:
        raise ValueError(f"arrival rate must be > 0, got {rate}")
    trace = RequestTrace(process=spec.process, rate_rps=rate,
                         horizon_s=spec.horizon_s, seed=seed)
    t = 0.0
    for k in range(int(spec.max_requests)):
        rng = np.random.default_rng([seed, k])
        if spec.process == "diurnal":
            lam = _diurnal_rate(rate, spec.diurnal_amp,
                                spec.diurnal_period_s, t)
        else:
            lam = rate
        t = t + float(rng.exponential(1.0)) / lam
        if t > spec.horizon_s:
            break
        trace.requests.append(Request(
            rid=k, t_arrival=t,
            prompt_len=_lognormal_len(rng, spec.prompt_mean,
                                      spec.prompt_sigma, 1,
                                      int(spec.prompt_max)),
            output_len=_lognormal_len(rng, spec.output_mean,
                                      spec.output_sigma, 1,
                                      int(spec.output_max))))
    return trace
