"""Batched serving: prefill + greedy/temperature decode over the jit'd steps.

The decode step is the unit the dry-run lowers for the decode_32k/long_500k
cells: one new token against a static-shape KV cache (ring buffer for
sliding-window archs, O(1) states for SSM/RWKV).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0            # 0 -> greedy
    seed: int = 0


def sample_token(logits, temperature: float, key):
    """logits: (B, 1, V) -> (B, 1) int32."""
    if temperature <= 0.0:
        return jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    probs = jax.nn.softmax(logits[:, -1] / temperature, axis=-1)
    return jax.random.categorical(key, jnp.log(probs + 1e-30))[
        :, None].astype(jnp.int32)


def generate(model, params, batch: Dict[str, Any], cfg: ServeConfig,
             prefill_fn=None, decode_fn=None) -> np.ndarray:
    """Returns (B, max_new_tokens) generated ids."""
    prefill_fn = prefill_fn or jax.jit(model.prefill)
    decode_fn = decode_fn or jax.jit(model.decode_step)
    key = jax.random.PRNGKey(cfg.seed)
    logits, cache = prefill_fn(params, batch)
    out: List[jnp.ndarray] = []
    tok = sample_token(logits, cfg.temperature, key)
    out.append(tok)
    for i in range(cfg.max_new_tokens - 1):
        key, sub = jax.random.split(key)
        logits, cache = decode_fn(params, tok, cache)
        tok = sample_token(logits, cfg.temperature, sub)
        out.append(tok)
    return np.asarray(jnp.concatenate(out, axis=1))


class ServingLoop:
    """Minimal batched-request loop: collects requests into fixed-size
    batches (static shapes!), pads the shortfall, runs prefill+decode."""

    def __init__(self, model, params, batch_size: int, prompt_len: int,
                 cfg: Optional[ServeConfig] = None):
        self.model = model
        self.params = params
        self.B = batch_size
        self.S = prompt_len
        self.cfg = cfg or ServeConfig()
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)
        # reusable padded input: serve() writes each request batch into
        # this preallocated (B, S) buffer instead of allocating a fresh
        # pad block + concatenation per call
        self._pad_buf = np.zeros((self.B, self.S), np.int32)

    def serve(self, prompts: np.ndarray) -> np.ndarray:
        """prompts: (n, S) int32, n <= batch_size.  Pads to B, returns (n, T)."""
        prompts = np.asarray(prompts)
        if prompts.ndim != 2 or prompts.shape[1] != self.S:
            raise ValueError(
                f"prompts must have shape (n, {self.S}) — static shapes: "
                f"pad/truncate ragged prompts before serving; got "
                f"{prompts.shape}")
        n = prompts.shape[0]
        if n > self.B:
            raise ValueError(
                f"batch of {n} prompts exceeds batch_size={self.B}; split "
                f"the batch or raise batch_size (got {prompts.shape})")
        buf = self._pad_buf
        buf[:n] = prompts
        buf[n:] = 0
        batch = {"tokens": jnp.asarray(buf)}
        toks = generate(self.model, self.params, batch, self.cfg,
                        self._prefill, self._decode)
        return toks[:n]
