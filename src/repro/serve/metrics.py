"""SLO metrics over request records: tails, goodput, offline replay.

``slo_summary`` condenses a population of :class:`RequestRecord` rows into
the serving SLO surface (definitions in docs/serving.md):

  * ``ttft_p50`` / ``ttft_p99``        — time-to-first-token quantiles (s)
  * ``tpot_p50`` / ``tpot_p99``        — per-output-token latency quantiles
  * ``queue_wait_p99``                 — admission-wait tail (s)
  * ``goodput_rps``                    — requests completed *within both
    deadlines* per simulated second (goodput-under-deadline)
  * ``slo_attainment``                 — fraction of the offered population
    meeting both deadlines
  * ``tokens_per_s``                   — decoded tokens per simulated second

plus per-node ``ttft_p99_node{n}`` columns and their max/spread, so a
thermal straggler shows up as *which node's* tail inflated.

Every value is NaN-free by construction: quantiles over an empty
population report the ``-1.0`` sentinel (the runner's ``_num``
convention), never NaN — the CI smoke asserts this.

``replay_slo`` recomputes the same summary offline from a saved JSONL
trace (``request`` lines + the ``meta["serve"]`` block).  Floats survive
the JSONL round trip exactly (shortest-repr doubles, NaN as null), and the
replay runs the identical arithmetic on the identical population, so live
and replayed summaries match bit-for-bit — tested, and checked by
scripts/serve_smoke.py in CI.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional

import numpy as np

from repro.telemetry.collector import RequestRecord

__all__ = ["SLO_METRICS", "slo_summary", "replay_slo", "slo_replay_matches"]

# the fleet-wide SLO metric names every summary carries (docs/serving.md
# must mention each; scripts/check_docs.py enforces it)
SLO_METRICS = ("ttft_p50", "ttft_p99", "tpot_p50", "tpot_p99",
               "queue_wait_p99", "goodput_rps", "slo_attainment",
               "tokens_per_s")


def _q(values: List[float], q: float) -> float:
    """Quantile with the empty-population sentinel (-1.0, never NaN)."""
    return float(np.quantile(values, q)) if values else -1.0


def slo_summary(records: Iterable[RequestRecord], ttft_deadline_s: float,
                tpot_deadline_s: float, t_elapsed_s: float,
                n_nodes: Optional[int] = None) -> Dict[str, float]:
    """The flat, JSON-safe SLO metric dict for one request population.

    ``records`` must be the *full offered population* (completed and
    flushed-incomplete rows); ``t_elapsed_s`` is the fleet-mean simulated
    serving time the rate metrics are normalized by.
    """
    recs = list(records)
    ttfts = [r.ttft for r in recs if r.t_first == r.t_first]
    tpots = [r.tpot for r in recs if r.complete]
    waits = [r.queue_wait for r in recs if r.t_admit == r.t_admit]
    n_ok = sum(1 for r in recs
               if r.complete and r.ttft <= ttft_deadline_s
               and r.tpot <= tpot_deadline_s)
    tokens = sum(r.tokens_out for r in recs)
    t = max(float(t_elapsed_s), 1e-12)
    out: Dict[str, float] = {
        "offered": float(len(recs)),
        "completed": float(sum(1 for r in recs if r.complete)),
        "first_tokens": float(len(ttfts)),
        "ttft_p50": _q(ttfts, 0.50),
        "ttft_p99": _q(ttfts, 0.99),
        "tpot_p50": _q(tpots, 0.50),
        "tpot_p99": _q(tpots, 0.99),
        "queue_wait_p99": _q(waits, 0.99),
        "goodput_rps": n_ok / t,
        "slo_attainment": (n_ok / len(recs)) if recs else -1.0,
        "tokens_per_s": tokens / t,
    }
    if n_nodes is not None:
        per_node = []
        for n in range(int(n_nodes)):
            node_ttfts = [r.ttft for r in recs
                          if r.node == n and r.t_first == r.t_first]
            p99 = _q(node_ttfts, 0.99)
            out[f"ttft_p99_node{n}"] = p99
            per_node.append(p99)
        finite = [p for p in per_node if p >= 0]
        out["ttft_p99_node_max"] = max(finite) if finite else -1.0
        out["ttft_p99_node_spread"] = (max(finite) - min(finite)
                                       if finite else -1.0)
    return out


def replay_slo(trace) -> Dict[str, float]:
    """Recompute the SLO summary offline from a loaded ``TelemetryTrace``.

    Uses only what the JSONL carries — the ``request`` rows and the
    ``meta["serve"]`` block (deadlines, elapsed fleet time, node count) —
    and must reproduce the live run's summary bit-for-bit.
    """
    ms = trace.meta.get("serve")
    if not ms:
        raise ValueError("trace carries no serve metadata "
                         "(meta['serve']); was it recorded by a serve/* "
                         "scenario?")
    return slo_summary(trace.requests,
                       ttft_deadline_s=float(ms["ttft_deadline_s"]),
                       tpot_deadline_s=float(ms["tpot_deadline_s"]),
                       t_elapsed_s=float(ms["t_fleet_s"]),
                       n_nodes=int(ms["n_nodes"]))


def slo_replay_matches(live: Dict[str, float], replayed: Dict[str, float],
                       log=None) -> bool:
    """Exact (bit-for-bit) comparison of two SLO summaries; differences
    are reported through ``log`` (a callable taking one string)."""
    ok = True
    for key in sorted(set(live) | set(replayed)):
        a, b = live.get(key), replayed.get(key)
        if a != b:
            ok = False
            if log is not None:
                log(f"  {key}: live {a!r} != replayed {b!r}")
    return ok
