"""Continuous batching over C3Sim kernel windows.

The mapping onto the simulator (docs/serving.md): one engine step of a
node is one full C3 iteration — a static-shape fused serving iteration,
exactly the shape discipline the seed ``ServingLoop`` enforces (fixed
batch, padded slots).  What the batcher decides is *which requests ride
each iteration*:

  * **prefill** — an admitted request's prompt is chewed through in
    ``prefill_chunk``-token chunks, one chunk per engine step (the
    compute-heavy window); the step that consumes the final chunk also
    produces the first output token (TTFT stops here);
  * **decode** — every slot past prefill emits exactly one token per
    engine step (the short latency-bound iteration);
  * **slot recycling** — a request completing its ``output_len`` frees
    its slot at the end of the step; free slots refill FIFO from the
    node's queue at the *start* of the next step.

So a thermally throttled node doesn't drop work — its engine steps
simply take longer, every slot's tokens arrive later, the queue backs up,
and the backlog compounds into TTFT tail inflation.  That is the Lit
Silicon serving effect the SLO metrics measure.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional

from repro.serve.traffic import Request
from repro.telemetry.collector import RequestRecord

__all__ = ["BatchSlot", "ContinuousBatcher"]

NAN = float("nan")


@dataclass
class BatchSlot:
    """One occupied batch slot: a request plus its serving progress."""

    req: Request
    t_admit: float
    prefill_done: int = 0           # prompt tokens already prefetched
    tokens_out: int = 0             # output tokens produced
    t_first: float = NAN            # set when prefill completes

    @property
    def in_prefill(self) -> bool:
        return self.prefill_done < self.req.prompt_len


@dataclass
class ContinuousBatcher:
    """Fixed-capacity slot pool + FIFO queue for one serving node."""

    slots: int
    prefill_chunk: int
    node: int = 0
    queue: Deque[Request] = field(default_factory=deque)
    active: List[Optional[BatchSlot]] = field(init=False)

    def __post_init__(self):
        if self.slots < 1:
            raise ValueError(f"batch_slots must be >= 1, got {self.slots}")
        if self.prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, "
                             f"got {self.prefill_chunk}")
        self.active = [None] * self.slots
        self.first_token_events = []

    # ------------------------------------------------------------- accessors
    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.active)

    @property
    def n_queued(self) -> int:
        return len(self.queue)

    def oldest_unserved_age(self, now: float) -> float:
        """Age of the oldest request still waiting for its first token
        (queued, or admitted but mid-prefill) — the head-of-line half of
        the tail-latency manager signal: it grows even while nothing
        completes, so a backlogged node is visible immediately."""
        oldest = math.inf
        for r in self.queue:
            oldest = min(oldest, r.t_arrival)
        for s in self.active:
            if s is not None and s.t_first != s.t_first:
                oldest = min(oldest, s.req.t_arrival)
        return 0.0 if oldest is math.inf else max(0.0, now - oldest)

    # ------------------------------------------------------------- lifecycle
    def enqueue(self, req: Request) -> None:
        self.queue.append(req)

    def admit(self, now: float) -> int:
        """Refill free slots FIFO from the queue; returns admissions."""
        n = 0
        for i, s in enumerate(self.active):
            if s is None and self.queue:
                self.active[i] = BatchSlot(self.queue.popleft(), t_admit=now)
                n += 1
        return n

    def step(self, t_end: float) -> List[RequestRecord]:
        """Advance every occupied slot through one engine step ending at
        ``t_end`` (the node's clock after this C3 iteration); returns the
        records of requests that completed during the step.  First-token
        events land in ``first_token_events`` as ``(t_first, ttft)`` pairs
        the serving engine drains into its tail-signal window (TTFT is
        observable at first-token time, well before completion)."""
        done: List[RequestRecord] = []
        for i, s in enumerate(self.active):
            if s is None:
                continue
            if s.in_prefill:
                s.prefill_done += self.prefill_chunk
                if not s.in_prefill:            # final chunk → first token
                    s.t_first = t_end
                    s.tokens_out = 1
                    self.first_token_events.append(
                        (t_end, t_end - s.req.t_arrival))
            else:
                s.tokens_out += 1
            if s.tokens_out >= s.req.output_len:
                done.append(RequestRecord(
                    rid=s.req.rid, node=self.node,
                    t_arrival=s.req.t_arrival, t_admit=s.t_admit,
                    t_first=s.t_first, t_done=t_end,
                    prompt_len=s.req.prompt_len,
                    output_len=s.req.output_len, tokens_out=s.tokens_out))
                self.active[i] = None
        return done

    def flush(self) -> List[RequestRecord]:
        """Drain every unfinished request (occupied slots, then the queue)
        as incomplete records — NaN where the milestone never happened —
        so a trace carries the full offered population."""
        out: List[RequestRecord] = []
        for i, s in enumerate(self.active):
            if s is None:
                continue
            out.append(RequestRecord(
                rid=s.req.rid, node=self.node, t_arrival=s.req.t_arrival,
                t_admit=s.t_admit, t_first=s.t_first, t_done=NAN,
                prompt_len=s.req.prompt_len, output_len=s.req.output_len,
                tokens_out=s.tokens_out))
            self.active[i] = None
        while self.queue:
            r = self.queue.popleft()
            out.append(RequestRecord(
                rid=r.rid, node=self.node, t_arrival=r.t_arrival,
                t_admit=NAN, t_first=NAN, t_done=NAN,
                prompt_len=r.prompt_len, output_len=r.output_len,
                tokens_out=0))
        return out
