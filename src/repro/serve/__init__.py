"""Serving on C3Sim: request traces, continuous batching, SLO metrics.

The jax-backed decode loop (`ServeConfig`, `ServingLoop`, `generate`) is
imported lazily so the pure-numpy serving scenario stack (traffic /
batcher / metrics / engine — everything `python -m repro run serve/...`
touches) never pays the jax import.
"""
from repro.serve.batcher import BatchSlot, ContinuousBatcher
from repro.serve.engine import ServeReport, ServingFleet
from repro.serve.metrics import (SLO_METRICS, replay_slo, slo_replay_matches,
                                 slo_summary)
from repro.serve.traffic import (ARRIVAL_PROCESSES, Request, RequestTrace,
                                 generate_requests)

__all__ = [
    "ARRIVAL_PROCESSES", "Request", "RequestTrace", "generate_requests",
    "BatchSlot", "ContinuousBatcher",
    "SLO_METRICS", "slo_summary", "replay_slo", "slo_replay_matches",
    "ServingFleet", "ServeReport",
    "ServeConfig", "ServingLoop", "generate",
]

_DECODE_EXPORTS = {"ServeConfig", "ServingLoop", "generate", "sample_token"}


def __getattr__(name):
    if name in _DECODE_EXPORTS:
        from repro.serve import decode
        return getattr(decode, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
