from repro.serve.decode import ServeConfig, ServingLoop, generate

__all__ = ["ServeConfig", "ServingLoop", "generate"]
